//! Step 1 of the online selection workflow (paper Fig. 2 / §4.3):
//! uniform blockwise sampling.
//!
//! Blocks are taken on a fixed stride so samples spread uniformly over
//! the field ("the distance between two data blocks sampled nearby will
//! be fixed in the same dimension"), making the estimate deterministic
//! — no RNG on the request path.

use crate::data::field::Dims;
use crate::zfp::block::{block_grid, block_size};

/// Default Stage-I sampling rate (paper: 5% balances accuracy and
/// overhead; Tables 2–5 sweep 1/5/10%).
pub const DEFAULT_RSP: f64 = 0.05;

/// Embedded-coding pointwise subsample counts per block (paper §5.2.2:
/// 3 points per 1D block, 9 per 4×4, 16 per 4×4×4).
pub const fn ec_samples_per_block(ndim: usize) -> usize {
    match ndim {
        1 => 3,
        2 => 9,
        _ => 16,
    }
}

/// A blockwise sample of a field.
#[derive(Clone, Debug)]
pub struct BlockSample {
    /// Sampled block coordinates (bz, by, bx).
    pub blocks: Vec<(usize, usize, usize)>,
    /// Total blocks in the field.
    pub total_blocks: usize,
    /// Field dims.
    pub dims: Dims,
}

/// Select every k-th block so that ≈ `r_sp` of all blocks are sampled.
/// Always samples at least one block.
pub fn sample_blocks(dims: Dims, r_sp: f64) -> BlockSample {
    assert!(r_sp > 0.0 && r_sp <= 1.0, "sampling rate {r_sp} out of (0,1]");
    let g = block_grid(dims);
    let total = g[0] * g[1] * g[2];
    let stride = ((1.0 / r_sp).round() as usize).max(1);
    // Offset by stride/2 so samples sit mid-stride (uniform coverage
    // even when the field has edge effects).
    let first = (stride / 2).min(total.saturating_sub(1));
    let mut blocks = Vec::with_capacity(total / stride + 1);
    let mut lin = first;
    while lin < total {
        let bz = lin / (g[1] * g[2]);
        let rem = lin % (g[1] * g[2]);
        blocks.push((bz, rem / g[2], rem % g[2]));
        lin += stride;
    }
    if blocks.is_empty() {
        blocks.push((0, 0, 0));
    }
    BlockSample { blocks, total_blocks: total, dims }
}

impl BlockSample {
    /// Achieved sampling rate (fraction of blocks).
    pub fn rate(&self) -> f64 {
        self.blocks.len() as f64 / self.total_blocks as f64
    }

    /// Number of sampled data points (block count × block size; edge
    /// blocks count padded size — the estimator works on padded blocks).
    pub fn num_points(&self) -> usize {
        self.blocks.len() * block_size(self.dims.ndim())
    }

    /// Linear indices of all *valid* (in-range) points inside the
    /// sampled blocks — the SZ estimator's sample set.
    pub fn point_indices(&self) -> Vec<usize> {
        let e = self.dims.extents();
        let (nz, ny, nx) = (e[0], e[1], e[2]);
        let mut idx = Vec::with_capacity(self.num_points());
        match self.dims.ndim() {
            1 => {
                for &(_, _, bx) in &self.blocks {
                    for i in 0..4 {
                        let x = bx * 4 + i;
                        if x < nx {
                            idx.push(x);
                        }
                    }
                }
            }
            2 => {
                for &(_, by, bx) in &self.blocks {
                    for j in 0..4 {
                        let y = by * 4 + j;
                        if y >= ny {
                            continue;
                        }
                        for i in 0..4 {
                            let x = bx * 4 + i;
                            if x < nx {
                                idx.push(y * nx + x);
                            }
                        }
                    }
                }
            }
            _ => {
                for &(bz, by, bx) in &self.blocks {
                    for k in 0..4 {
                        let z = bz * 4 + k;
                        if z >= nz {
                            continue;
                        }
                        for j in 0..4 {
                            let y = by * 4 + j;
                            if y >= ny {
                                continue;
                            }
                            for i in 0..4 {
                                let x = bx * 4 + i;
                                if x < nx {
                                    idx.push((z * ny + y) * nx + x);
                                }
                            }
                        }
                    }
                }
            }
        }
        idx
    }
}

/// Deterministic within-block EC subsample: `count` coefficient ranks
/// spread evenly over the sequency order `0..bs` (includes rank 0, the
/// DC coefficient, and the last rank — the staircase endpoints the
/// interpolation needs).
pub fn ec_sample_ranks(ndim: usize) -> Vec<usize> {
    let bs = block_size(ndim);
    let count = ec_samples_per_block(ndim).min(bs);
    if count >= bs {
        return (0..bs).collect();
    }
    (0..count)
        .map(|i| i * (bs - 1) / (count - 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::field::Dims;

    #[test]
    fn rate_is_close_to_requested() {
        let dims = Dims::D2(400, 400); // 100x100 = 10,000 blocks
        for r in [0.01, 0.05, 0.10] {
            let s = sample_blocks(dims, r);
            assert!(
                (s.rate() - r).abs() / r < 0.1,
                "requested {r}, got {}",
                s.rate()
            );
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let dims = Dims::D3(20, 20, 20);
        let a = sample_blocks(dims, 0.05);
        let b = sample_blocks(dims, 0.05);
        assert_eq!(a.blocks, b.blocks);
    }

    #[test]
    fn tiny_field_samples_at_least_one_block() {
        let s = sample_blocks(Dims::D1(4), 0.01);
        assert_eq!(s.blocks.len(), 1);
    }

    #[test]
    fn point_indices_in_range_and_unique() {
        let dims = Dims::D2(37, 41); // partial edge blocks
        let s = sample_blocks(dims, 0.25);
        let idx = s.point_indices();
        assert!(!idx.is_empty());
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), idx.len(), "duplicate sample indices");
        assert!(idx.iter().all(|&i| i < dims.len()));
    }

    #[test]
    fn blocks_spread_across_field() {
        let dims = Dims::D2(400, 400);
        let s = sample_blocks(dims, 0.05);
        // Samples should span most of the block-row range.
        let max_by = s.blocks.iter().map(|b| b.1).max().unwrap();
        let min_by = s.blocks.iter().map(|b| b.1).min().unwrap();
        assert!(max_by - min_by > 80, "rows {min_by}..{max_by}");
    }

    #[test]
    fn ec_ranks_cover_endpoints() {
        for ndim in 1..=3 {
            let ranks = ec_sample_ranks(ndim);
            assert_eq!(ranks.len(), ec_samples_per_block(ndim).min(block_size(ndim)));
            assert_eq!(ranks[0], 0);
            assert_eq!(*ranks.last().unwrap(), block_size(ndim) - 1);
            // strictly increasing
            assert!(ranks.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
