//! SZ compression-quality model (paper §5.1, Eqs. 6, 9, 10, 11).
//!
//! Bit-rate: Shannon entropy of the quantization-bin distribution of
//! Stage-I prediction errors (Eq. 9) plus the empirical +0.5 bit/value
//! offset (§6.2: Huffman coding does not reach the entropy bound;
//! 0.5 bits/value calibrated on real simulation data), plus the literal
//! cost of unpredictable points.
//!
//! PSNR: closed form, depends only on the bin size (Eq. 10/11) —
//! "the PSNR depends only on the unified quantization bin size
//! regardless of the distribution of transformed data".

use super::pdf::ErrorPdf;
use super::sampling::BlockSample;
use crate::data::field::Dims;
use crate::sz::lorenzo;

/// The paper's empirical Huffman-inefficiency offset (bits/value).
pub const BR_OFFSET: f64 = 0.5;

/// Literal cost (bits) per unpredictable value: escape code ≈ entropy
/// already counts the escape symbol; the f32 payload adds 32 bits.
pub const LITERAL_BITS: f64 = 32.0;

/// An SZ quality estimate.
#[derive(Clone, Copy, Debug)]
pub struct SzEstimate {
    /// Estimated bits/value (Eq. 9 + offset + literals).
    pub bit_rate: f64,
    /// Estimated PSNR in dB (Eq. 10).
    pub psnr: f64,
    /// Fraction of sampled points that were unpredictable.
    pub escape_frac: f64,
}

/// Closed-form PSNR for linear quantization with bin size δ (Eq. 10):
/// PSNR = 20·log10(VR/δ) + 10·log10(12).
pub fn psnr_from_delta(delta: f64, value_range: f64) -> f64 {
    if value_range <= 0.0 || delta <= 0.0 {
        return f64::INFINITY;
    }
    20.0 * (value_range / delta).log10() + 10.0 * 12.0f64.log10()
}

/// Closed-form PSNR from the value-range-relative error bound (Eq. 11):
/// PSNR = −20·log10(eb_rel) + 10·log10(3), with δ = 2·eb_abs.
pub fn psnr_from_eb_rel(eb_rel: f64) -> f64 {
    -20.0 * eb_rel.log10() + 10.0 * 3.0f64.log10()
}

/// Invert Eq. 10: the bin size δ that yields a target PSNR.
pub fn delta_from_psnr(psnr: f64, value_range: f64) -> f64 {
    // δ = VR · √12 · 10^(−PSNR/20)
    value_range * 12.0f64.sqrt() * 10.0f64.powf(-psnr / 20.0)
}

/// Serialized Huffman-table cost per symbol: delta-varint symbol
/// (dense alphabets → 1 byte) + varint code length (1 byte).
pub const TABLE_BITS_PER_SYMBOL: f64 = 16.0;

/// Estimate SZ's bit-rate (Eq. 9 + offset) from a prediction-error PDF.
///
/// Beyond the paper's Eq. 9 + 0.5 offset we add two corrections that
/// matter on rough fields at tight bounds (alphabet ≫ sample size):
/// full-size entropy extrapolation (plug-in entropy of a 5% sample is
/// capped at log2(m)) and the Huffman-table cost, both driven by the
/// Poisson-occupancy richness model in [`ErrorPdf::extrapolate`].
/// Both corrections vanish on the smooth fields the paper evaluates
/// (k ≪ m), so the model stays faithful where the paper's +0.5 offset
/// was calibrated.
pub fn bit_rate_from_pdf(pdf: &ErrorPdf, field_len: usize) -> f64 {
    let esc = pdf.escape_prob();
    let (h, k_n) = pdf.extrapolate(field_len);
    let table_bits = k_n * TABLE_BITS_PER_SYMBOL / field_len.max(1) as f64;
    h + BR_OFFSET + esc * LITERAL_BITS + table_bits
}

/// Plug-in bit-rate for an *atomic* (lattice-supported) prediction-
/// error distribution — the bitround+SZ pipeline's regime.
///
/// After the bitround pre-stage, values sit on the lattice `q·Z`, so
/// prediction errors are lattice points exactly: the distribution is a
/// discrete set of atoms, one per quantization bin, not a continuous
/// density. Two of [`bit_rate_from_pdf`]'s corrections therefore do
/// not apply: the locally-flat density refinement (there is no
/// sub-bin structure to spread mass over — each atom IS its bin) and
/// the Poisson richness inflation (the alphabet is capped by the
/// occupied lattice sites, which the sample observes directly). The
/// sampled histogram is the full-field distribution up to sampling
/// noise, so plug-in entropy + observed-occupancy table cost are the
/// honest estimate. This is what lets the pipeline win on rough fields
/// at tight bounds: it pays +0.5 bits for splitting the budget
/// (δ → δ/√2) but skips the ~log2(N/m) extrapolation penalty.
pub fn bit_rate_from_pdf_atomic(pdf: &ErrorPdf, field_len: usize) -> f64 {
    let esc = pdf.escape_prob();
    let table_bits = pdf.occupied_bins() as f64 * TABLE_BITS_PER_SYMBOL / field_len.max(1) as f64;
    pdf.entropy() + BR_OFFSET + esc * LITERAL_BITS + table_bits
}

/// Estimate the bitround+SZ pipeline column at operating point
/// `eb_pipe` (the pipeline's absolute bound): the bitround quantum and
/// the core SZ bin width are both `eb_pipe`, two independent uniform
/// quantizers whose MSEs sum to `eb_pipe²/6` — the distortion of a
/// single quantizer with δ_eff = eb_pipe·√2, which is how the PSNR is
/// reported. The sampled PDF is passed through the
/// [`ErrorPdf::bitround`] stage transform, then priced with the
/// atomic (plug-in) rate model.
pub fn estimate_bitround(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    eb_pipe: f64,
    capacity: u32,
    value_range: f64,
) -> SzEstimate {
    let idx = sample.point_indices();
    let errors = lorenzo::prediction_errors_original(data, dims, &idx);
    let pdf = ErrorPdf::build(&errors, eb_pipe, capacity).bitround(eb_pipe);
    SzEstimate {
        bit_rate: bit_rate_from_pdf_atomic(&pdf, data.len()),
        psnr: psnr_from_delta(eb_pipe * std::f64::consts::SQRT_2, value_range),
        escape_frac: pdf.escape_prob(),
    }
}

/// Full SZ estimate for a field: Stage-I transform (Lorenzo with
/// original neighbors, §4.3) on the sampled points, then Eqs. 9/10.
///
/// `delta` is the quantization bin size (2·eb for plain SZ; derived
/// from ZFP's PSNR in Algorithm 1).
pub fn estimate(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    delta: f64,
    capacity: u32,
    value_range: f64,
) -> SzEstimate {
    let idx = sample.point_indices();
    let errors = lorenzo::prediction_errors_original(data, dims, &idx);
    let pdf = ErrorPdf::build(&errors, delta, capacity);
    SzEstimate {
        bit_rate: bit_rate_from_pdf(&pdf, data.len()),
        psnr: psnr_from_delta(delta, value_range),
        escape_frac: pdf.escape_prob(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectral::grf_2d;
    use crate::estimator::sampling::sample_blocks;
    use crate::metrics::{bit_rate, error_stats};
    use crate::sz::SzCompressor;
    use crate::testing::Rng;

    #[test]
    fn eq10_eq11_consistent() {
        // Eq. 11 is Eq. 10 with δ = 2·eb_abs and eb_rel = eb_abs/VR.
        let vr = 123.0;
        let eb_rel = 1e-4;
        let delta = 2.0 * eb_rel * vr;
        let a = psnr_from_delta(delta, vr);
        let b = psnr_from_eb_rel(eb_rel);
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn delta_from_psnr_inverts() {
        let vr = 7.5;
        for delta in [1e-6, 1e-3, 0.1] {
            let p = psnr_from_delta(delta, vr);
            let d = delta_from_psnr(p, vr);
            assert!((d - delta).abs() < 1e-9 * delta);
        }
    }

    #[test]
    fn psnr_estimate_matches_real_sz_within_2db() {
        // End-to-end: the Eq. 11 PSNR must track the real SZ PSNR
        // (paper: within ~1–2% — SZ errors are near-uniform in bins).
        let mut rng = Rng::new(141);
        let f = grf_2d(&mut rng, 128, 128, 2.5);
        let dims = Dims::D2(128, 128);
        let vr = crate::metrics::value_range(&f);
        let eb = 1e-3 * vr;
        let sz = SzCompressor::default();
        let comp = sz.compress(&f, dims, eb).unwrap();
        let (recon, _) = sz.decompress(&comp).unwrap();
        let real = error_stats(&f, &recon);
        let est = psnr_from_delta(2.0 * eb, vr);
        assert!(
            (est - real.psnr).abs() < 2.0,
            "est {est:.2} dB vs real {:.2} dB",
            real.psnr
        );
        // The estimate is conservative (paper: estimated ≤ real).
        assert!(est <= real.psnr + 0.5);
    }

    #[test]
    fn bit_rate_estimate_tracks_real_sz() {
        let mut rng = Rng::new(142);
        let f = grf_2d(&mut rng, 160, 160, 3.0);
        let dims = Dims::D2(160, 160);
        let vr = crate::metrics::value_range(&f);
        let eb = 1e-4 * vr;

        let sample = sample_blocks(dims, 0.05);
        let est = estimate(&f, dims, &sample, 2.0 * eb, 65_535, vr);

        let sz = SzCompressor::default();
        let comp = sz.compress(&f, dims, eb).unwrap();
        let real_br = bit_rate(comp.len(), f.len());
        let rel = (est.bit_rate - real_br) / real_br;
        assert!(
            rel.abs() < 0.25,
            "BR est {:.3} vs real {real_br:.3} (rel {rel:.3})",
            est.bit_rate
        );
    }

    #[test]
    fn bitround_pipeline_beats_plain_sz_on_rough_fields() {
        // Rough field at a tight bound: the sample sees mostly
        // singleton bins, so plain SZ's extrapolated entropy pays the
        // locally-flat refinement (~log2(N/m) bits) while the atomic
        // model pays only the δ→δ/√2 half bit. The composed column
        // must come out strictly cheaper — the mechanism behind the
        // pipeline acceptance row in the ablations bench.
        let f = crate::data::atm::generate_field_scaled(3, 7, 1); // Rough class
        let vr = crate::metrics::value_range(&f.data);
        let eb = 1e-4 * vr;
        let delta = 2.0 * eb;
        let sample = sample_blocks(f.dims, 0.05);
        let plain = estimate(&f.data, f.dims, &sample, delta, 65_535, vr);
        let eb_pipe = (delta / std::f64::consts::SQRT_2).min(eb);
        let pipe = estimate_bitround(&f.data, f.dims, &sample, eb_pipe, 65_535, vr);
        assert!(
            pipe.bit_rate < plain.bit_rate,
            "atomic {:.3} b/v should beat extrapolated {:.3} b/v",
            pipe.bit_rate,
            plain.bit_rate
        );
        // Iso-or-better PSNR: with δ ≤ √2·eb the operating points have
        // identical MSE; in the pointwise-clamped regime (δ = 2·eb
        // here, so eb_pipe = eb < δ/√2) the pipeline's distortion is
        // strictly better. Never worse.
        assert!(pipe.psnr >= plain.psnr - 1e-9, "{} vs {}", pipe.psnr, plain.psnr);
        // On a smooth, well-sampled field the two models agree to
        // within the extrapolation corrections (no free lunch there).
        let smooth = crate::data::atm::generate_field_scaled(3, 0, 0);
        let svr = crate::metrics::value_range(&smooth.data);
        let seb = 1e-3 * svr;
        let ssample = sample_blocks(smooth.dims, 0.05);
        let splain = estimate(&smooth.data, smooth.dims, &ssample, 2.0 * seb, 65_535, svr);
        let spipe = estimate_bitround(
            &smooth.data,
            smooth.dims,
            &ssample,
            (2.0 * seb / std::f64::consts::SQRT_2).min(seb),
            65_535,
            svr,
        );
        assert!(
            spipe.bit_rate > splain.bit_rate - 0.2,
            "smooth fields should not spuriously favor the pipeline: {} vs {}",
            spipe.bit_rate,
            splain.bit_rate
        );
    }

    #[test]
    fn escape_fraction_detected_on_noise() {
        // White noise + tiny delta => most samples unpredictable.
        let mut rng = Rng::new(143);
        let f: Vec<f32> = (0..4096).map(|_| rng.range_f64(-1e3, 1e3) as f32).collect();
        let dims = Dims::D1(4096);
        let sample = sample_blocks(dims, 0.25);
        let est = estimate(&f, dims, &sample, 1e-9, 65_535, 2e3);
        assert!(est.escape_frac > 0.9, "escape {}", est.escape_frac);
        assert!(est.bit_rate > 30.0, "literal cost should dominate");
    }
}
