//! The paper's contribution: online, low-overhead estimation of each
//! candidate codec's compression quality (bit-rate + PSNR) from a
//! small blockwise sample, and rate-distortion-optimal selection
//! (Algorithm 1, generalized from SZ-vs-ZFP to the registered codec
//! set — SZ, ZFP, DCT).
//!
//! * [`sampling`] — Step 1: uniform blockwise sampling (rate r_sp) and
//!   pointwise EC subsampling (rate r_sp^ec).
//! * [`pdf`] — approximate probability density of prediction errors.
//! * [`sz_model`] — Eqs. 6/9/11: entropy-based bit-rate (+0.5 offset)
//!   and closed-form PSNR for linear quantization.
//! * [`zfp_model`] — §5.2: significant-bit staircase interpolation
//!   (n̄_sb) for bit-rate, sampled truncation error for PSNR.
//! * [`dct_model`] — §7 extension: Eq. 9 entropy bit-rate on sampled
//!   DCT coefficients, Eq. 10 PSNR on the coefficient bin size.
//! * [`quant_models`] — §5.1.4 closed forms for log-scale and
//!   equal-probability quantization (analysis/ablations).
//! * [`selector`] — Algorithm 1 + the compression front end.
//! * [`eval`] — ground-truth measurement helpers used by the Table 2–5
//!   benches.

pub mod dct_model;
pub mod eval;
pub mod multiway;
pub mod pdf;
pub mod quant_models;
pub mod sampling;
pub mod selector;
pub mod stage_model;
pub mod sz_model;
pub mod zfp_model;

pub use selector::{AutoSelector, CandidateSet, Choice, PipelineMask, SelectorConfig};
