//! Closed-form quality models for the alternative Stage-II quantizers
//! of paper §5.1.4 — log-scale and equal-probability quantization.
//! Used by the `ablation_quant` bench to reproduce the paper's
//! qualitative claims: log-scale trades compression ratio for PSNR;
//! equal-probability neutralizes entropy coding entirely.

use super::pdf::ErrorPdf;

/// Rate/distortion estimate for one quantizer choice.
#[derive(Clone, Copy, Debug)]
pub struct QuantEstimate {
    pub bit_rate: f64,
    pub psnr: f64,
}

/// Linear quantization (paper Eqs. 9/10) — thin wrapper for symmetry
/// with the other two models.
pub fn linear_model(pdf: &ErrorPdf, value_range: f64) -> QuantEstimate {
    QuantEstimate {
        bit_rate: pdf.entropy(),
        psnr: super::sz_model::psnr_from_delta(pdf.delta, value_range),
    }
}

/// Log-scale quantization model (§5.1.4): bins δ_{n±i} = bᶦ − bᶦ⁻¹.
/// Bit-rate from Eq. 6 over the log-binned PDF; PSNR from Eq. 8's
/// (1/12)·Σ δᵢ³·P(mᵢ).
pub fn log_scale_model(
    errors: &[f32],
    n_half: u32,
    value_range: f64,
) -> QuantEstimate {
    assert!(n_half >= 2);
    let max_abs = errors.iter().fold(0.0f64, |m, &e| m.max((e as f64).abs()));
    let q = crate::sz::quant::LogQuantizer::new(max_abs.max(1e-300), n_half);
    let nbins = (2 * n_half - 1) as usize;
    let mut counts = vec![0u64; nbins];
    for &e in errors {
        counts[q.quantize(e as f64) as usize] += 1;
    }
    let total = errors.len().max(1) as f64;
    // Eq. 6: entropy of the bin occupancy.
    let bit_rate = crate::metrics::entropy_from_counts(&counts);
    // Eq. 8: MSE = (1/12)·Σ δᵢ³·P(mᵢ) = (1/12)·Σ δᵢ²·Pᵢ
    // (with Pᵢ = δᵢ·P(mᵢ) the bin probability).
    let mut mse = 0.0f64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let delta_i = q.bin_width(i as u32);
        mse += delta_i * delta_i / 12.0 * (c as f64 / total);
    }
    QuantEstimate { bit_rate, psnr: crate::metrics::psnr_from_mse(mse, value_range) }
}

/// Equal-probability quantization model (§5.1.4, NUMARCK-style):
/// bit-rate = log2(2n−1) exactly (uniform symbols defeat entropy
/// coding); PSNR from the fitted bin widths.
pub fn equal_prob_model(errors: &[f32], num_bins: u32, value_range: f64) -> QuantEstimate {
    let vals: Vec<f64> = errors.iter().map(|&e| e as f64).collect();
    let q = crate::sz::quant::EqualProbQuantizer::fit(&vals, num_bins);
    let bit_rate = (num_bins as f64).log2();
    let total = vals.len().max(1) as f64;
    let mut counts = vec![0u64; num_bins as usize];
    for &v in &vals {
        counts[q.quantize(v) as usize] += 1;
    }
    let mut mse = 0.0f64;
    for (i, &c) in counts.iter().enumerate() {
        let w = q.edges[i + 1] - q.edges[i];
        mse += w * w / 12.0 * (c as f64 / total);
    }
    QuantEstimate { bit_rate, psnr: crate::metrics::psnr_from_mse(mse, value_range) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::pdf::ErrorPdf;
    use crate::testing::Rng;

    fn gauss_errors(n: usize, sigma: f64, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.gauss() * sigma) as f32).collect()
    }

    #[test]
    fn log_scale_beats_linear_psnr_loses_rate() {
        // Paper §5.1.4: log-scale usually has higher PSNR but lower
        // compression ratio (higher bit-rate via flatter occupancy).
        let errs = gauss_errors(200_000, 0.1, 161);
        let vr = 100.0;
        let delta = 0.05;
        let lin = linear_model(&ErrorPdf::build(&errs, delta, 255), vr);
        let log = log_scale_model(&errs, 128, vr);
        assert!(log.psnr > lin.psnr, "log {:.1} vs lin {:.1}", log.psnr, lin.psnr);
    }

    #[test]
    fn equal_prob_bitrate_is_log2_bins() {
        let errs = gauss_errors(10_000, 1.0, 162);
        let est = equal_prob_model(&errs, 31, 10.0);
        assert!((est.bit_rate - 31.0f64.log2()).abs() < 1e-12);
    }

    #[test]
    fn equal_prob_psnr_finite_and_positive() {
        let errs = gauss_errors(10_000, 0.01, 163);
        let est = equal_prob_model(&errs, 63, 10.0);
        assert!(est.psnr.is_finite() && est.psnr > 0.0);
    }

    #[test]
    fn more_bins_higher_psnr() {
        let errs = gauss_errors(50_000, 0.5, 164);
        let few = equal_prob_model(&errs, 15, 10.0);
        let many = equal_prob_model(&errs, 255, 10.0);
        assert!(many.psnr > few.psnr);
    }
}
