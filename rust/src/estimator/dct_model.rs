//! DCT (SSEM-style) compression-quality model — the third column of
//! the multi-way selection matrix (paper §7 extension).
//!
//! The DCT codec is a *static-quantization* transform coder, so its
//! estimate reuses the §5.1 machinery on **DCT coefficients** instead
//! of prediction errors: sample blocks → forward DCT → coefficient
//! PDF → Eq. 9 entropy bit-rate (with the same Huffman offset, escape
//! and table corrections as [`super::sz_model`]).
//!
//! PSNR is closed-form in the coefficient bin size by Theorem 3: the
//! transform is orthogonal, so coefficient-domain MSE equals
//! data-domain MSE and Eq. 10 applies to δ_c directly.

use super::pdf::ErrorPdf;
use super::sampling::BlockSample;
use super::sz_model;
use crate::data::field::Dims;
use crate::zfp::block::{self, block_size};
use crate::zfp::transform::{ParametricBot, T_DCT2};

/// A DCT quality estimate.
#[derive(Clone, Copy, Debug)]
pub struct DctEstimate {
    /// Estimated bits/value (Eq. 9 on the coefficient PDF + offset).
    pub bit_rate: f64,
    /// Estimated PSNR in dB (Eq. 10 on the coefficient bin size).
    pub psnr: f64,
    /// Fraction of sampled coefficients outside the quantizer range.
    pub escape_frac: f64,
}

/// Estimate the DCT codec's quality from sampled blocks at coefficient
/// bin size `delta_c`.
pub fn estimate(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    delta_c: f64,
    capacity: u32,
    field_len: usize,
    value_range: f64,
) -> DctEstimate {
    let pdf = coefficient_pdf(data, dims, sample, delta_c, capacity);
    DctEstimate {
        bit_rate: sz_model::bit_rate_from_pdf(&pdf, field_len),
        psnr: sz_model::psnr_from_delta(delta_c, value_range),
        escape_frac: pdf.escape_prob(),
    }
}

/// Build the quantization-bin PDF of the sampled blocks' DCT
/// coefficients — the transform-domain analogue of the SZ
/// prediction-error PDF. Shared by per-field estimation and the
/// chunk-level field prior (DESIGN.md §11).
pub fn coefficient_pdf(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    delta_c: f64,
    capacity: u32,
) -> ErrorPdf {
    let ndim = dims.ndim();
    let bs = block_size(ndim);
    let bot = ParametricBot::new(T_DCT2);
    let mut fblock = vec![0.0f32; bs];
    let mut dblock = vec![0.0f64; bs];
    let mut coeffs: Vec<f32> = Vec::with_capacity(sample.blocks.len() * bs);
    for &coords in &sample.blocks {
        block::gather(data, dims, coords, &mut fblock);
        for (d, &f) in dblock.iter_mut().zip(&fblock) {
            *d = f as f64;
        }
        bot.forward(&mut dblock, ndim);
        coeffs.extend(dblock.iter().map(|&c| c as f32));
    }
    ErrorPdf::build(&coeffs, delta_c, capacity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectral::grf_2d;
    use crate::dct::compressor::coeff_delta;
    use crate::dct::DctCompressor;
    use crate::estimator::sampling::sample_blocks;
    use crate::metrics::bit_rate;
    use crate::testing::Rng;

    #[test]
    fn bit_rate_estimate_tracks_real_dct() {
        let mut rng = Rng::new(171);
        let f = grf_2d(&mut rng, 160, 160, 2.5);
        let dims = Dims::D2(160, 160);
        let vr = crate::metrics::value_range(&f);
        let eb = 1e-4 * vr;

        let sample = sample_blocks(dims, 0.05);
        let est = estimate(&f, dims, &sample, coeff_delta(eb, 2), 65_535, f.len(), vr);

        let comp = DctCompressor::default().compress(&f, dims, eb).unwrap();
        let real_br = bit_rate(comp.len(), f.len());
        let rel = (est.bit_rate - real_br) / real_br;
        assert!(
            rel.abs() < 0.30,
            "BR est {:.3} vs real {real_br:.3} (rel {rel:.3})",
            est.bit_rate
        );
    }

    #[test]
    fn tighter_delta_raises_estimated_bitrate() {
        let mut rng = Rng::new(172);
        let f = grf_2d(&mut rng, 96, 96, 2.0);
        let dims = Dims::D2(96, 96);
        let vr = crate::metrics::value_range(&f);
        let sample = sample_blocks(dims, 0.1);
        let loose = estimate(&f, dims, &sample, coeff_delta(1e-2 * vr, 2), 65_535, f.len(), vr);
        let tight = estimate(&f, dims, &sample, coeff_delta(1e-5 * vr, 2), 65_535, f.len(), vr);
        assert!(tight.bit_rate > loose.bit_rate, "{tight:?} vs {loose:?}");
        assert!(tight.psnr > loose.psnr);
    }
}
