//! Approximate probability density of Stage-I transformed data
//! (prediction errors), built from the sampled points (paper Fig. 4,
//! §5.1, memory-overhead analysis §6.3.2).
//!
//! The PDF is held as a histogram over the *quantization bins* directly
//! (width δ, centered on zero), so the Eq. 6/9 entropy estimate is an
//! exact sum over histogram probabilities: with P(mᵢ) = Pᵢ/δ,
//! −Σ δ·P(mᵢ)·log2(δ·P(mᵢ)) = −Σ Pᵢ·log2 Pᵢ.

/// Histogram of prediction errors over 2n−1 linear quantization bins
/// plus out-of-range (escape) mass.
#[derive(Clone, Debug)]
pub struct ErrorPdf {
    /// Bin width δ.
    pub delta: f64,
    /// Counts per bin; index n−1 is the zero-centered bin.
    pub counts: Vec<u64>,
    /// Samples falling outside the binned range ("unpredictable").
    pub escape_count: u64,
    /// Total samples.
    pub total: u64,
}

impl ErrorPdf {
    /// Build from prediction errors with `capacity` bins (2n−1, odd) of
    /// width `delta`.
    pub fn build(errors: &[f32], delta: f64, capacity: u32) -> Self {
        assert!(delta > 0.0 && delta.is_finite());
        assert!(capacity >= 3);
        let n = (capacity / 2) as i64; // bins: indices 0..2n-2, center n-1
        let nbins = (2 * n - 1) as usize;
        let mut counts = vec![0u64; nbins];
        let mut escape = 0u64;
        let inv_delta = 1.0 / delta;
        for &e in errors {
            let q = (e as f64 * inv_delta).round();
            if q.abs() < n as f64 {
                counts[(q as i64 + n - 1) as usize] += 1;
            } else {
                escape += 1;
            }
        }
        ErrorPdf { delta, counts, escape_count: escape, total: errors.len() as u64 }
    }

    /// Probability of the escape symbol.
    pub fn escape_prob(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.escape_count as f64 / self.total as f64
        }
    }

    /// Shannon entropy (bits/value) of the bin distribution, escape
    /// included as one extra symbol — Eq. 5 of the paper.
    pub fn entropy(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let n = self.total as f64;
        let mut h = 0.0;
        for &c in self.counts.iter().chain(std::iter::once(&self.escape_count)) {
            if c > 0 {
                let p = c as f64 / n;
                h -= p * p.log2();
            }
        }
        h
    }

    /// Expected Stage-II MSE under midpoint reconstruction — Eq. 7/8's
    /// (1/12)·Σ δᵢ³·P(mᵢ) specialised to equal bins: δ²/12 · P(in-range).
    pub fn expected_mse(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let in_range = (self.total - self.escape_count) as f64 / self.total as f64;
        self.delta * self.delta / 12.0 * in_range
    }

    /// Number of occupied bins (observed symbol richness k_m).
    pub fn occupied_bins(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
            + (self.escape_count > 0) as usize
    }

    /// Extrapolate (entropy bits/value, distinct-symbol count) from the
    /// m-point sample to the full field of `field_len` points.
    ///
    /// A 5% sample of a heavy-tailed alphabet sees only a fraction of
    /// the symbols (plug-in entropy is capped at log2(m)), yet the
    /// Huffman table and code lengths scale with the *full-size*
    /// alphabet. Model: the sampled tail behaves as K equally-likely
    /// bins under Poisson occupancy — fit K from k_m = K·(1−e^(−m/K)),
    /// then k_N = K·(1−e^(−N/K)). Entropy splits into a well-observed
    /// head (counts ≥ 2, plug-in) and the singleton mass u = f1/m
    /// spread over the extrapolated tail. For well-sampled (smooth)
    /// fields f1 ≈ 0 and K ≈ k_m, so both quantities reduce to the
    /// plug-in values — the regime where the paper's +0.5 offset was
    /// calibrated stays untouched.
    /// Method: prediction errors follow a smooth continuous density, so
    /// we estimate the density on *coarse* bins of g = ⌈N/m⌉ fine bins
    /// (where the sample has meaningful counts), then refine: a smooth
    /// density is locally flat, so coarse mass q_j spreads uniformly
    /// over its g sub-bins — H gains q_j·log2(g) and occupancy follows
    /// Poisson filling. Coarse bins whose sub-structure *is* observable
    /// (count ≫ occupied sub-bins: point masses like saturated zeros)
    /// keep their fine plug-in contribution instead.
    pub fn extrapolate(&self, field_len: usize) -> (f64, f64) {
        if self.total == 0 {
            return (0.0, 0.0);
        }
        let m = self.total as f64;
        let n = field_len as f64;
        let nb = self.counts.len();
        let capacity = (nb + 1) as f64;
        let g = ((n / m).ceil() as usize).max(1);

        let mut h = 0.0f64;
        let mut k_n = 0.0f64;
        let mut j = 0usize;
        while j < nb {
            let hi = (j + g).min(nb);
            let c_j: u64 = self.counts[j..hi].iter().sum();
            if c_j > 0 {
                let s_j = self.counts[j..hi].iter().filter(|&&c| c > 0).count();
                let q_j = c_j as f64 / m;
                // Observable sub-structure: average ≥ 3 samples per
                // occupied fine bin (point masses, well-sampled cores).
                if c_j as usize >= 3 * s_j.max(1) && s_j >= 1 {
                    for &c in &self.counts[j..hi] {
                        if c > 0 {
                            let p = c as f64 / m;
                            h -= p * p.log2();
                        }
                    }
                    k_n += s_j as f64;
                } else {
                    // Unobservable: assume locally flat density.
                    let width = (hi - j) as f64;
                    h += q_j * (width / q_j).log2();
                    // Poisson occupancy of sub-bins at N draws:
                    // λ per sub-bin = N·q_j/width.
                    let lam = n * q_j / width;
                    k_n += width * (1.0 - (-lam).exp());
                }
            }
            j = hi;
        }
        // Escape symbol contributes as one plug-in symbol.
        if self.escape_count > 0 {
            let p = self.escape_count as f64 / m;
            h -= p * p.log2();
            k_n += 1.0;
        }
        let h = h.min(capacity.min(n).log2()).max(0.0);
        let k_n = k_n.min(capacity).min(n);
        (h, k_n)
    }

    /// Per-stage PDF transform (DESIGN.md §15): predict the effect of
    /// a bit-rounding pre-stage with quantum `quantum` on this error
    /// histogram. Rounding the *inputs* to the lattice `quantum·Z`
    /// makes every downstream Lorenzo prediction error a lattice point
    /// too (predictions are ± sums of lattice values), so each bin's
    /// mass moves to the bin of its center snapped to the lattice.
    /// With `quantum` equal to the bin width the transform is the
    /// identity — the histogram's own binning already performs the
    /// snap — and larger quanta concentrate mass (entropy never
    /// rises). Escape mass stays escape.
    pub fn bitround(&self, quantum: f64) -> ErrorPdf {
        assert!(quantum > 0.0 && quantum.is_finite());
        let nb = self.counts.len();
        let n = (nb as i64 + 1) / 2; // counts.len() = 2n−1
        let mut counts = vec![0u64; nb];
        let mut escape = self.escape_count;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let center = (i as i64 - (n - 1)) as f64 * self.delta;
            let snapped = (center / quantum).round() * quantum;
            let q = (snapped / self.delta).round();
            if q.abs() < n as f64 {
                counts[(q as i64 + n - 1) as usize] += c;
            } else {
                escape += c;
            }
        }
        ErrorPdf { delta: self.delta, counts, escape_count: escape, total: self.total }
    }

    /// Measure of symmetry: |P(left wing) − P(right wing)| (paper
    /// assumes symmetric pred-error distributions; tested on our data).
    pub fn asymmetry(&self) -> f64 {
        let mid = self.counts.len() / 2;
        let left: u64 = self.counts[..mid].iter().sum();
        let right: u64 = self.counts[mid + 1..].iter().sum();
        if self.total == 0 {
            return 0.0;
        }
        (left as f64 - right as f64).abs() / self.total as f64
    }

    /// Downsampled histogram series for plotting (Fig. 4): returns
    /// (bin center, probability) pairs for `resolution` aggregated bins.
    pub fn series(&self, resolution: usize) -> Vec<(f64, f64)> {
        let nb = self.counts.len();
        let group = nb.div_ceil(resolution.max(1));
        let n = (nb + group - 1) / group;
        let center = (nb / 2) as f64;
        (0..n)
            .map(|g| {
                let lo = g * group;
                let hi = (lo + group).min(nb);
                let c: u64 = self.counts[lo..hi].iter().sum();
                let mid_bin = (lo + hi) as f64 / 2.0 - center;
                (
                    mid_bin * self.delta,
                    c as f64 / self.total.max(1) as f64,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn gaussian_errors_are_centered_and_symmetric() {
        let mut rng = Rng::new(131);
        let errs: Vec<f32> = (0..100_000).map(|_| (rng.gauss() * 0.01) as f32).collect();
        let pdf = ErrorPdf::build(&errs, 0.002, 65535);
        assert_eq!(pdf.escape_count, 0);
        assert!(pdf.asymmetry() < 0.01, "asymmetry {}", pdf.asymmetry());
        // Center bin should be the mode.
        let center = pdf.counts.len() / 2;
        let max_idx = pdf
            .counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, &c)| c)
            .unwrap()
            .0;
        assert!((max_idx as i64 - center as i64).abs() <= 1);
    }

    #[test]
    fn entropy_bounds() {
        let mut rng = Rng::new(132);
        let errs: Vec<f32> = (0..50_000).map(|_| (rng.gauss() * 0.01) as f32).collect();
        let pdf = ErrorPdf::build(&errs, 0.002, 65535);
        let h = pdf.entropy();
        assert!(h > 0.0 && h < 16.0, "entropy {h}");
        // Wider bins -> lower entropy.
        let pdf_wide = ErrorPdf::build(&errs, 0.02, 65535);
        assert!(pdf_wide.entropy() < h);
    }

    #[test]
    fn escape_mass_counted() {
        let errs = vec![1000.0f32; 100];
        let pdf = ErrorPdf::build(&errs, 0.001, 15); // range ±7δ
        assert_eq!(pdf.escape_count, 100);
        assert_eq!(pdf.escape_prob(), 1.0);
        assert_eq!(pdf.entropy(), 0.0); // single (escape) symbol
    }

    #[test]
    fn expected_mse_uniform_in_bin() {
        // All errors uniform in the central bin: MSE ≈ δ²/12.
        let mut rng = Rng::new(133);
        let delta = 0.1;
        let errs: Vec<f32> = (0..100_000)
            .map(|_| rng.range_f64(-delta / 2.0, delta / 2.0) as f32)
            .collect();
        let pdf = ErrorPdf::build(&errs, delta, 255);
        let expect = delta * delta / 12.0;
        assert!((pdf.expected_mse() - expect).abs() < expect * 0.01);
    }

    #[test]
    fn bitround_transform_identity_and_concentration() {
        let mut rng = Rng::new(135);
        let errs: Vec<f32> = (0..50_000).map(|_| (rng.gauss() * 0.05) as f32).collect();
        let delta = 0.004;
        let pdf = ErrorPdf::build(&errs, delta, 4095);
        // quantum == bin width: the binning already snaps, identity.
        let same = pdf.bitround(delta);
        assert_eq!(same.counts, pdf.counts);
        assert_eq!(same.escape_count, pdf.escape_count);
        // Coarser quantum concentrates mass: entropy must not rise and
        // total mass is conserved.
        let coarse = pdf.bitround(4.0 * delta);
        assert_eq!(coarse.total, pdf.total);
        let mass = |p: &ErrorPdf| p.counts.iter().sum::<u64>() + p.escape_count;
        assert_eq!(mass(&coarse), mass(&pdf));
        assert!(coarse.entropy() <= pdf.entropy() + 1e-12);
        assert!(coarse.occupied_bins() <= pdf.occupied_bins());
    }

    #[test]
    fn series_sums_to_one() {
        let mut rng = Rng::new(134);
        let errs: Vec<f32> = (0..10_000).map(|_| (rng.gauss() * 0.05) as f32).collect();
        let pdf = ErrorPdf::build(&errs, 0.01, 1023);
        let s = pdf.series(64);
        let sum: f64 = s.iter().map(|&(_, p)| p).sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum {sum}");
    }
}
