//! Algorithm 1: the automatic online selection method, generalized
//! from the paper's SZ-vs-ZFP decision to a multi-way ranking over the
//! registered candidate codecs (SZ, ZFP, DCT — §7 extension).
//!
//! Per field: estimate ZFP's bit-rate and PSNR from the sample (ZFP
//! anchors the iso-distortion target because its PSNR is data-driven);
//! derive the SZ quantization bin size δ and the DCT coefficient bin
//! size δ_c that match that PSNR (Eq. 10 inversion, Theorem 3);
//! estimate every candidate's bit-rate at its iso-PSNR operating
//! point; pick the candidate with the smallest estimated bit-rate;
//! compress. The output carries the selection bit s_i (paper's output
//! format) plus the estimates for observability.

use super::sampling::{sample_blocks, DEFAULT_RSP};
use super::{dct_model, stage_model, sz_model, zfp_model};
use crate::codec_api::{
    builtin_pipeline_id, builtin_pipeline_name, CodecRegistry, FIRST_PIPELINE_ID, MAX_COMPOSED,
    PIPE_BITROUND_SZ, PIPE_BITROUND_SZ_SHUFFLE, PIPE_BITROUND_ZFP, PIPE_DELTA_ARITH,
    PIPE_DELTA_HUFF,
};
use crate::data::field::{Dims, Field};
use crate::dct::compressor::coeff_delta;
use crate::dct::DctConfig;
use crate::sz::SzConfig;
use crate::zfp::block::block_size;
use crate::zfp::ZfpConfig;
use crate::{Error, Result};

// `Choice` is now a thin wrapper over codec-registry ids; re-exported
// here so `estimator::selector::Choice` keeps working.
pub use crate::codec_api::Choice;

/// Bit-set of composed pipeline ids competing in the ranking
/// (selection bytes ≥ [`FIRST_PIPELINE_ID`]). A newtype over `u64` so
/// [`CandidateSet`] stays `Copy` — the whole selector config is passed
/// by value through the coordinator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineMask(pub u64);

impl PipelineMask {
    /// No composed pipelines (the default — bare codecs only, which
    /// keeps default outputs byte-identical to the flat registry).
    pub const NONE: PipelineMask = PipelineMask(0);

    /// Every built-in composed pipeline.
    pub fn builtins() -> Self {
        let mut m = PipelineMask::NONE;
        let mut id = FIRST_PIPELINE_ID;
        while builtin_pipeline_name(id).is_some() {
            m.insert(id);
            id += 1;
        }
        m
    }

    /// Enable pipeline `id` (ignores out-of-range ids ≥ 64).
    pub fn insert(&mut self, id: u8) {
        if id < 64 {
            self.0 |= 1u64 << id;
        }
    }

    /// `true` if pipeline `id` competes.
    pub fn contains(self, id: u8) -> bool {
        id < 64 && self.0 & (1u64 << id) != 0
    }

    /// `true` if any pipeline competes.
    pub fn any(self) -> bool {
        self.0 != 0
    }

    /// Enabled pipeline ids in ascending order.
    pub fn ids(self) -> impl Iterator<Item = u8> {
        (0u8..64).filter(move |&id| self.contains(id))
    }
}

/// Which codecs compete in the ranking. `Raw` never competes — it is
/// the no-compression policy, not a rate-distortion candidate.
/// Composed pipelines (DESIGN.md §15) compete only when enabled in
/// `pipelines`; the default mask is empty so default selections (and
/// therefore default outputs) match the historical flat registry
/// byte-for-byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandidateSet {
    pub sz: bool,
    pub zfp: bool,
    pub dct: bool,
    pub pipelines: PipelineMask,
}

impl Default for CandidateSet {
    fn default() -> Self {
        CandidateSet::all()
    }
}

impl CandidateSet {
    /// Every registered rate-distortion codec (the default). Composed
    /// pipelines stay opt-in.
    pub const fn all() -> Self {
        CandidateSet { sz: true, zfp: true, dct: true, pipelines: PipelineMask::NONE }
    }

    /// The paper's original Algorithm 1 matrix (SZ vs ZFP) — used by
    /// the Table 2–5 / Fig. 6–9 reproductions for fidelity.
    pub const fn two_way() -> Self {
        CandidateSet { sz: true, zfp: true, dct: false, pipelines: PipelineMask::NONE }
    }

    /// Parse a comma-separated candidate list: bare codec names
    /// (`sz`, `zfp`, `dct`) and/or built-in pipeline names
    /// (`bitround+sz`, `delta+arith`, …), e.g. `"sz,bitround+sz"`.
    /// Empty tokens (trailing commas) are ignored; an entirely empty
    /// list is an error.
    pub fn parse(s: &str) -> Result<Self> {
        let mut set =
            CandidateSet { sz: false, zfp: false, dct: false, pipelines: PipelineMask::NONE };
        for tok in s.split(',') {
            match tok.trim().to_ascii_lowercase().as_str() {
                "" => {}
                "sz" => set.sz = true,
                "zfp" => set.zfp = true,
                "dct" => set.dct = true,
                other => match builtin_pipeline_id(other) {
                    Some(id) => set.pipelines.insert(id),
                    None => {
                        return Err(Error::InvalidArg(format!(
                            "unknown candidate '{other}' (expected sz, zfp, dct, or a \
                             built-in pipeline such as bitround+sz)"
                        )))
                    }
                },
            }
        }
        if !(set.sz || set.zfp || set.dct) && !set.pipelines.any() {
            return Err(Error::InvalidArg("empty codec set".into()));
        }
        Ok(set)
    }

    /// Enabled candidates in stable ranking order (ties resolve toward
    /// the earlier, longer-validated codec: SZ, then ZFP, then DCT,
    /// then composed pipelines by ascending id).
    pub fn choices(self) -> impl Iterator<Item = Choice> {
        [
            (self.sz, Choice::Sz),
            (self.zfp, Choice::Zfp),
            (self.dct, Choice::Dct),
        ]
        .into_iter()
        .filter_map(|(on, c)| on.then_some(c))
        .chain(self.pipelines.ids().map(Choice::Pipeline))
    }

    /// `true` if `choice` competes in this set.
    pub fn contains(self, choice: Choice) -> bool {
        match choice {
            Choice::Sz => self.sz,
            Choice::Zfp => self.zfp,
            Choice::Dct => self.dct,
            Choice::Raw => false,
            Choice::Pipeline(id) => self.pipelines.contains(id),
        }
    }

    /// Comma-separated names of the enabled candidates.
    pub fn names(self) -> String {
        self.choices().map(|c| c.name()).collect::<Vec<_>>().join(",")
    }

    /// Rank: smallest estimated bit-rate wins; strict `<` so ties keep
    /// the earliest candidate in [`CandidateSet::choices`] order. NaN
    /// estimates never win.
    pub fn rank(self, est: &Estimates) -> Result<Choice> {
        let mut best: Option<(Choice, f64)> = None;
        for c in self.choices() {
            let br = est.bit_rate_of(c);
            if br.is_nan() {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, b)) => br < b,
            };
            if better {
                best = Some((c, br));
            }
        }
        best.map(|(c, _)| c).ok_or_else(|| {
            Error::InvalidArg("no rankable codec candidate (empty set or NaN estimates)".into())
        })
    }
}

/// Selector configuration.
#[derive(Clone, Copy, Debug)]
pub struct SelectorConfig {
    /// Stage-I blockwise sampling rate r_sp.
    pub r_sp: f64,
    /// SZ quantization capacity.
    pub capacity: u32,
    pub sz: SzConfig,
    pub zfp: ZfpConfig,
    pub dct: DctConfig,
    pub zfp_model: zfp_model::ZfpModelConfig,
    /// Codecs competing in the ranking (default: SZ, ZFP, DCT).
    pub candidates: CandidateSet,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            r_sp: DEFAULT_RSP,
            capacity: 65_535,
            sz: SzConfig::default(),
            zfp: ZfpConfig::default(),
            dct: DctConfig::default(),
            zfp_model: zfp_model::ZfpModelConfig::default(),
            candidates: CandidateSet::all(),
        }
    }
}

/// Estimates computed by Algorithm 1 (lines 5–9), one column per
/// candidate codec.
#[derive(Clone, Copy, Debug)]
pub struct Estimates {
    pub br_sz: f64,
    pub br_zfp: f64,
    pub br_dct: f64,
    /// The iso-distortion target PSNR (ZFP's estimated PSNR).
    pub psnr_target: f64,
    /// Absolute error bound handed to SZ (δ/2, ≤ the user bound).
    pub eb_sz: f64,
    /// Absolute error bound handed to ZFP (the user bound).
    pub eb_zfp: f64,
    /// Absolute pointwise bound handed to DCT (≤ the user bound; the
    /// codec derives its own coefficient bin size δ_c from it).
    pub eb_dct: f64,
    /// Composed-pipeline bit-rate columns, slot `id −
    /// FIRST_PIPELINE_ID` (∞ when not estimated / not a candidate).
    pub br_pipe: [f64; MAX_COMPOSED],
    /// Absolute bound handed to each composed pipeline (its iso-PSNR
    /// operating point, ≤ the user bound).
    pub eb_pipe: [f64; MAX_COMPOSED],
}

impl Estimates {
    fn pipe_slot(id: u8) -> Option<usize> {
        let slot = (id as usize).wrapping_sub(FIRST_PIPELINE_ID as usize);
        (slot < MAX_COMPOSED).then_some(slot)
    }

    /// The bound Algorithm 1 hands to `choice`'s codec: SZ, DCT and
    /// the composed pipelines get their iso-PSNR bounds, every other
    /// codec the user bound.
    pub fn bound_for(&self, choice: Choice) -> f64 {
        match choice {
            Choice::Sz => self.eb_sz,
            Choice::Dct => self.eb_dct,
            Choice::Pipeline(id) => match Self::pipe_slot(id) {
                Some(s) => self.eb_pipe[s],
                None => self.eb_zfp,
            },
            _ => self.eb_zfp,
        }
    }

    /// Estimated bit-rate of one candidate.
    pub fn bit_rate_of(&self, choice: Choice) -> f64 {
        match choice {
            Choice::Sz => self.br_sz,
            Choice::Zfp => self.br_zfp,
            Choice::Dct => self.br_dct,
            Choice::Raw => 32.0,
            Choice::Pipeline(id) => match Self::pipe_slot(id) {
                Some(s) => self.br_pipe[s],
                None => f64::INFINITY,
            },
        }
    }
}

/// Result of selection + compression for one field.
#[derive(Clone, Debug)]
pub struct CompressOutput {
    pub choice: Choice,
    /// Self-describing payload: selection byte + codec stream.
    pub container: Vec<u8>,
    pub estimates: Estimates,
    /// Uncompressed size in bytes.
    pub raw_bytes: usize,
}

impl CompressOutput {
    /// Achieved compression ratio.
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.container.len() as f64
    }

    /// Achieved bit-rate (bits/value, f32 input). Computed in f64 so
    /// non-multiple-of-4 sizes don't floor; 0.0 for an empty field.
    pub fn bit_rate(&self) -> f64 {
        if self.raw_bytes == 0 {
            return 0.0;
        }
        self.container.len() as f64 * 8.0 / (self.raw_bytes as f64 / 4.0)
    }
}

/// The automatic online selector (Algorithm 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct AutoSelector {
    pub cfg: SelectorConfig,
}

impl AutoSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        AutoSelector { cfg }
    }

    /// The codec registry for this selector's configuration — the one
    /// place that maps selection bytes to concrete codecs.
    pub fn registry(&self) -> CodecRegistry {
        CodecRegistry::standard(self.cfg.sz, self.cfg.zfp, self.cfg.dct)
    }

    /// Algorithm 1 lines 2–10, multi-way: estimate every candidate at
    /// the shared target PSNR and choose. `eb_rel` is the
    /// value-range-based relative error bound; the absolute bound is
    /// eb = eb_rel · VR (line 2).
    pub fn select(&self, field: &Field, eb_rel: f64) -> Result<(Choice, Estimates)> {
        let vr = field.value_range();
        let eb = self.absolute_bound(vr, eb_rel)?;
        self.select_abs(field, eb, vr)
    }

    /// Selection with an explicit absolute bound.
    pub fn select_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<(Choice, Estimates)> {
        let est = self.estimate_abs(field, eb, vr)?;
        let choice = self.cfg.candidates.rank(&est)?;
        Ok((choice, est))
    }

    /// Estimate every candidate's iso-PSNR operating point and
    /// bit-rate (Algorithm 1 lines 3–9, one column per codec). Split
    /// from [`Self::select_abs`] so chunked runs can compute one
    /// field-level estimate and share it across chunks (DESIGN.md §11).
    pub fn estimate_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<Estimates> {
        if eb <= 0.0 || !eb.is_finite() {
            return Err(Error::InvalidArg(format!("bad error bound {eb}")));
        }
        // Line 3–4: blockwise + pointwise sampling.
        let sample = sample_blocks(field.dims, self.cfg.r_sp);

        // Lines 5–6: ZFP bit-rate (n̄_sb) and PSNR (PSNR_sp). ZFP is
        // always modeled — even when not a candidate — because its
        // data-driven PSNR anchors the iso-distortion target.
        let zfp_est =
            zfp_model::estimate(&field.data, field.dims, &sample, eb, vr, self.cfg.zfp_model);

        // Line 7: derive SZ's bin size from PSNR_sz := PSNR_zfp.
        // Clamp so SZ's pointwise bound never exceeds the user's bound
        // (ZFP over-preserves error, so normally δ/2 < eb already).
        let delta = if zfp_est.psnr.is_finite() && vr > 0.0 {
            sz_model::delta_from_psnr(zfp_est.psnr, vr).min(2.0 * eb)
        } else {
            2.0 * eb
        };
        let delta = if delta > 0.0 { delta } else { 2.0 * eb };

        // Lines 8–9: SZ PDF + bit-rate at that δ.
        let sz_est =
            sz_model::estimate(&field.data, field.dims, &sample, delta, self.cfg.capacity, vr);

        // DCT quantizes coefficients; Theorem 3 keeps MSE equal across
        // the orthogonal transform, so the iso-PSNR bin size δ applies
        // to the coefficient quantizer directly. Cap it at the
        // coefficient delta of the user bound so the pointwise
        // guarantee never loosens.
        let ndim = field.dims.ndim();
        let delta_dct = delta.min(coeff_delta(eb, ndim));
        let dct_est = if self.cfg.candidates.dct {
            dct_model::estimate(
                &field.data,
                field.dims,
                &sample,
                delta_dct,
                self.cfg.capacity,
                field.len(),
                vr,
            )
            .bit_rate
        } else {
            f64::INFINITY
        };

        // Composed-pipeline columns (DESIGN.md §15). Each enabled
        // pipeline is priced at its own iso-or-better operating point:
        // lossy pre-stage chains split the user bound, lossless chains
        // keep it. Columns for disabled pipelines stay at ∞ so they
        // never win the rank.
        let mut br_pipe = [f64::INFINITY; MAX_COMPOSED];
        let mut eb_pipe = [eb; MAX_COMPOSED];
        let mask = self.cfg.candidates.pipelines;
        if mask.any() {
            let slot = |id: u8| (id - FIRST_PIPELINE_ID) as usize;
            // bitround+sz(+shuffle): at pipeline bound E the codec
            // splits the budget so bitround quantum = SZ bin = E, two
            // uniform error sources adding in variance to δ_eff = E·√2.
            // Iso-PSNR with plain SZ's bin δ therefore sits at
            // E = δ/√2, clamped at the user bound (where the pipeline
            // is strictly *better* than the target, never worse). The
            // shuffle variant is order-0-coded, hence rate-identical.
            if mask.contains(PIPE_BITROUND_SZ) || mask.contains(PIPE_BITROUND_SZ_SHUFFLE) {
                let eb_p = (delta / std::f64::consts::SQRT_2).min(eb);
                let est = sz_model::estimate_bitround(
                    &field.data,
                    field.dims,
                    &sample,
                    eb_p,
                    self.cfg.capacity,
                    vr,
                );
                for id in [PIPE_BITROUND_SZ, PIPE_BITROUND_SZ_SHUFFLE] {
                    if mask.contains(id) {
                        br_pipe[slot(id)] = est.bit_rate;
                        eb_pipe[slot(id)] = eb_p;
                    }
                }
            }
            // bitround+zfp: no bespoke model for rounding-then-ZFP —
            // reuse ZFP's anchor column as a conservative stand-in
            // (the rounding stage can only concentrate the input).
            if mask.contains(PIPE_BITROUND_ZFP) {
                br_pipe[slot(PIPE_BITROUND_ZFP)] = zfp_est.bit_rate;
            }
            // Lossless delta chains: sampled byte statistics
            // (stage_model), full user bound untouched.
            if mask.contains(PIPE_DELTA_HUFF) || mask.contains(PIPE_DELTA_ARITH) {
                let le = stage_model::estimate_lossless_delta(
                    &field.data,
                    field.dims,
                    &sample,
                    field.len(),
                );
                if mask.contains(PIPE_DELTA_HUFF) {
                    br_pipe[slot(PIPE_DELTA_HUFF)] = le.huff_bits;
                }
                if mask.contains(PIPE_DELTA_ARITH) {
                    br_pipe[slot(PIPE_DELTA_ARITH)] = le.arith_bits;
                }
            }
        }

        Ok(Estimates {
            br_sz: sz_est.bit_rate,
            br_zfp: zfp_est.bit_rate,
            br_dct: dct_est,
            psnr_target: zfp_est.psnr,
            eb_sz: delta / 2.0,
            eb_zfp: eb,
            // The DCT codec takes a *pointwise* bound and derives its
            // own coefficient bin size; invert `coeff_delta`.
            eb_dct: delta_dct * (block_size(ndim) as f64).sqrt() / 2.0,
            br_pipe,
            eb_pipe,
        })
    }

    /// Full Algorithm 1: select, then compress with the chosen codec
    /// (lines 10–15). Output container = selection byte + codec stream.
    pub fn compress(&self, field: &Field, eb_rel: f64) -> Result<CompressOutput> {
        let vr = field.value_range();
        let eb = self.absolute_bound(vr, eb_rel)?;
        self.compress_abs(field, eb, vr)
    }

    /// Compression with an explicit absolute bound.
    pub fn compress_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<CompressOutput> {
        let (choice, estimates) = self.select_abs(field, eb, vr)?;
        // Paper output format: selection bit s_i + codec stream — the
        // registry frames both.
        let container =
            self.registry().encode(choice, &field.data, field.dims, estimates.bound_for(choice))?;
        Ok(CompressOutput { choice, container, estimates, raw_bytes: field.raw_bytes() })
    }

    /// Compress with a *forced* codec (baseline policies / Fig. 7 bars).
    pub fn compress_forced(&self, field: &Field, eb: f64, choice: Choice) -> Result<Vec<u8>> {
        self.registry().encode(choice, &field.data, field.dims, eb)
    }

    /// Decompress a container produced by [`Self::compress`].
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<f32>> {
        let (data, _dims) = self.decompress_with_dims(container)?;
        Ok(data)
    }

    /// Decompress, returning dims too. Dispatches on the leading
    /// selection byte through the codec registry.
    pub fn decompress_with_dims(&self, container: &[u8]) -> Result<(Vec<f32>, Dims)> {
        self.registry().decode(container)
    }

    fn absolute_bound(&self, vr: f64, eb_rel: f64) -> Result<f64> {
        if eb_rel <= 0.0 || !eb_rel.is_finite() {
            return Err(Error::InvalidArg(format!("bad relative bound {eb_rel}")));
        }
        // Constant fields have VR = 0; any tiny positive bound works.
        Ok(if vr > 0.0 { eb_rel * vr } else { eb_rel })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{atm, hurricane};
    use crate::metrics::error_stats;

    #[test]
    fn compress_roundtrip_respects_bound() {
        let sel = AutoSelector::default();
        for idx in [0usize, 4, 8] {
            let f = atm::generate_field_scaled(7, idx, 0);
            let vr = f.value_range();
            let out = sel.compress(&f, 1e-3).unwrap();
            let recon = sel.decompress(&out.container).unwrap();
            let stats = error_stats(&f.data, &recon);
            assert!(
                stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6),
                "field {idx} ({:?}): err {} bound {}",
                out.choice,
                stats.max_abs_err,
                1e-3 * vr
            );
        }
    }

    #[test]
    fn smooth_fields_pick_sz_rough_pick_zfp() {
        // The paper's original two-way matrix (Algorithm 1 as
        // published); DCT is excluded so the assertion stays the
        // SZ-vs-ZFP decision the paper validates.
        let cfg = SelectorConfig { candidates: CandidateSet::two_way(), ..Default::default() };
        let sel = AutoSelector::new(cfg);
        // idx 0 is a Smooth class (SZ-friendly); idx 7 is Rough.
        let smooth = atm::generate_field_scaled(11, 0, 1);
        let rough = atm::generate_field_scaled(11, 7, 1);
        let (cs, es) = sel.select(&smooth, 1e-4).unwrap();
        let (cr, er) = sel.select(&rough, 1e-4).unwrap();
        assert_eq!(cs, Choice::Sz, "smooth: {es:?}");
        assert_eq!(cr, Choice::Zfp, "rough: {er:?}");
    }

    #[test]
    fn three_way_pick_has_smallest_estimated_bitrate() {
        let sel = AutoSelector::default();
        for idx in [0usize, 3, 7] {
            let f = atm::generate_field_scaled(11, idx, 0);
            let (choice, est) = sel.select(&f, 1e-4).unwrap();
            let best = est.br_sz.min(est.br_zfp).min(est.br_dct);
            assert_eq!(est.bit_rate_of(choice), best, "idx {idx}: {est:?}");
        }
    }

    #[test]
    fn candidate_set_parse_and_rank() {
        assert_eq!(CandidateSet::parse("sz,zfp,dct").unwrap(), CandidateSet::all());
        assert_eq!(CandidateSet::parse("SZ , ZFP").unwrap(), CandidateSet::two_way());
        // Trailing commas are tolerated; empty lists are not.
        assert_eq!(CandidateSet::parse("sz,zfp,").unwrap(), CandidateSet::two_way());
        assert!(CandidateSet::parse("zstd").is_err());
        assert!(CandidateSet::parse("").is_err());
        assert!(CandidateSet::parse(",").is_err());
        let est = Estimates {
            br_sz: 2.0,
            br_zfp: 2.0,
            br_dct: 1.0,
            psnr_target: 60.0,
            eb_sz: 1.0,
            eb_zfp: 1.0,
            eb_dct: 1.0,
            br_pipe: [f64::INFINITY; MAX_COMPOSED],
            eb_pipe: [1.0; MAX_COMPOSED],
        };
        // Smallest BR wins; ties keep the earlier candidate.
        assert_eq!(CandidateSet::all().rank(&est).unwrap(), Choice::Dct);
        assert_eq!(CandidateSet::two_way().rank(&est).unwrap(), Choice::Sz);
        assert_eq!(CandidateSet::parse("dct").unwrap().names(), "DCT");
        assert!(CandidateSet::all().contains(Choice::Dct));
        assert!(!CandidateSet::all().contains(Choice::Raw));
    }

    #[test]
    fn candidate_set_parses_pipelines() {
        // Mixed codec + pipeline lists, case-insensitive.
        let set = CandidateSet::parse("sz,BitRound+SZ,delta+arith").unwrap();
        assert!(set.sz && !set.zfp && !set.dct);
        assert!(set.pipelines.contains(PIPE_BITROUND_SZ));
        assert!(set.pipelines.contains(PIPE_DELTA_ARITH));
        assert!(!set.pipelines.contains(PIPE_DELTA_HUFF));
        assert!(set.contains(Choice::Pipeline(PIPE_BITROUND_SZ)));
        assert!(!set.contains(Choice::Pipeline(PIPE_DELTA_HUFF)));
        // Pipeline-only lists are valid candidate sets.
        let only = CandidateSet::parse("bitround+sz+shuffle").unwrap();
        assert!(only.pipelines.contains(PIPE_BITROUND_SZ_SHUFFLE));
        assert_eq!(only.names(), "bitround+sz+shuffle");
        // choices() appends pipelines after bare codecs, ids ascending.
        let got: Vec<Choice> = set.choices().collect();
        assert_eq!(
            got,
            vec![
                Choice::Sz,
                Choice::Pipeline(PIPE_BITROUND_SZ),
                Choice::Pipeline(PIPE_DELTA_ARITH)
            ]
        );
        assert!(CandidateSet::parse("bitround+zstd").is_err());
        // Builtins mask covers every registered composed pipeline.
        let m = PipelineMask::builtins();
        for id in [
            PIPE_BITROUND_SZ,
            PIPE_BITROUND_ZFP,
            PIPE_BITROUND_SZ_SHUFFLE,
            PIPE_DELTA_HUFF,
            PIPE_DELTA_ARITH,
        ] {
            assert!(m.contains(id), "builtins missing id {id}");
        }
        assert!(!m.contains(Choice::Sz.id()));
    }

    #[test]
    fn pipeline_candidates_select_and_roundtrip() {
        // A pipeline-only candidate set must select, compress through
        // the staged registry, and decompress within the user bound.
        let cfg = SelectorConfig {
            candidates: CandidateSet::parse("bitround+sz,delta+arith").unwrap(),
            ..Default::default()
        };
        let sel = AutoSelector::new(cfg);
        let f = atm::generate_field_scaled(31, 7, 0);
        let vr = f.value_range();
        let out = sel.compress(&f, 1e-3).unwrap();
        assert!(matches!(out.choice, Choice::Pipeline(_)), "{:?}", out.choice);
        assert_eq!(out.container[0], out.choice.id());
        let recon = sel.decompress(&out.container).unwrap();
        let stats = error_stats(&f.data, &recon);
        assert!(
            stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6),
            "{:?}: err {} bound {}",
            out.choice,
            stats.max_abs_err,
            1e-3 * vr
        );
    }

    #[test]
    fn composed_pipeline_wins_on_rough_field_at_tight_bound() {
        // The acceptance scenario: with pipelines enabled alongside
        // the bare codecs, a rough field at a tight bound ranks
        // bitround+sz strictly below plain SZ's estimated bit-rate at
        // iso-PSNR (the atomic-distribution rate model skips the
        // richness extrapolation plain SZ pays for).
        let cfg = SelectorConfig {
            candidates: CandidateSet {
                pipelines: PipelineMask::builtins(),
                ..CandidateSet::all()
            },
            ..Default::default()
        };
        let sel = AutoSelector::new(cfg);
        let f = atm::generate_field_scaled(11, 7, 1); // Rough class
        let (_, est) = sel.select(&f, 1e-4).unwrap();
        let br_pipe = est.bit_rate_of(Choice::Pipeline(PIPE_BITROUND_SZ));
        assert!(
            br_pipe < est.br_sz,
            "bitround+sz {br_pipe} should beat plain SZ {} on rough data",
            est.br_sz
        );
        // The selected candidate carries the smallest estimate of all.
        let (choice, est) = sel.select(&f, 1e-4).unwrap();
        for c in sel.cfg.candidates.choices() {
            assert!(
                est.bit_rate_of(choice) <= est.bit_rate_of(c),
                "{choice:?} vs {c:?}"
            );
        }
        // And the winner's bound never loosens past the user's.
        let eb = f.value_range() * 1e-4;
        assert!(est.bound_for(choice) <= eb * (1.0 + 1e-12));
    }

    #[test]
    fn dct_only_candidates_select_and_roundtrip() {
        let cfg = SelectorConfig {
            candidates: CandidateSet::parse("dct").unwrap(),
            ..Default::default()
        };
        let sel = AutoSelector::new(cfg);
        let f = atm::generate_field_scaled(41, 2, 0);
        let vr = f.value_range();
        let out = sel.compress(&f, 1e-3).unwrap();
        assert_eq!(out.choice, Choice::Dct);
        assert_eq!(out.container[0], Choice::Dct.id());
        let recon = sel.decompress(&out.container).unwrap();
        let stats = error_stats(&f.data, &recon);
        assert!(
            stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6),
            "err {} bound {}",
            stats.max_abs_err,
            1e-3 * vr
        );
    }

    #[test]
    fn selection_bit_matches_choice() {
        let sel = AutoSelector::default();
        let f = hurricane::generate_field_scaled(3, 0, 0);
        let out = sel.compress(&f, 1e-3).unwrap();
        assert_eq!(out.container[0], out.choice.id());
    }

    #[test]
    fn iso_psnr_sz_bound_not_looser_than_user() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(13, 2, 0);
        let vr = f.value_range();
        let (_, est) = sel.select(&f, 1e-4).unwrap();
        assert!(est.eb_sz <= est.eb_zfp * (1.0 + 1e-12));
        assert!(est.eb_dct <= est.eb_zfp * (1.0 + 1e-12));
        assert!(est.eb_zfp > 0.0 && (est.eb_zfp - 1e-4 * vr).abs() < 1e-12 * vr);
    }

    #[test]
    fn constant_field_handled() {
        let f = Field::new("const", Dims::D2(64, 64), vec![2.5; 4096]);
        let sel = AutoSelector::default();
        let out = sel.compress(&f, 1e-4).unwrap();
        let recon = sel.decompress(&out.container).unwrap();
        assert!(recon.iter().all(|&v| (v - 2.5).abs() <= 1e-4));
        // A single-symbol Huffman stream costs 1 bit/value → ratio ≈ 32
        // minus header overhead (SZ-1.4 behaves the same without gzip).
        assert!(out.ratio() > 25.0, "constant field ratio {}", out.ratio());
    }

    #[test]
    fn forced_choice_roundtrip() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(17, 1, 0);
        let vr = f.value_range();
        for c in [Choice::Sz, Choice::Zfp, Choice::Dct] {
            let cont = sel.compress_forced(&f, 1e-3 * vr, c).unwrap();
            assert_eq!(cont[0], c.id());
            let recon = sel.decompress(&cont).unwrap();
            let stats = error_stats(&f.data, &recon);
            assert!(stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6), "{c:?}");
        }
    }

    #[test]
    fn rejects_bad_bounds() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(19, 0, 0);
        assert!(sel.compress(&f, 0.0).is_err());
        assert!(sel.compress(&f, -1.0).is_err());
        assert!(sel.compress(&f, f64::NAN).is_err());
    }

    #[test]
    fn bit_rate_guards_empty_and_fractional_sizes() {
        let mk = |raw_bytes: usize, stored: usize| CompressOutput {
            choice: Choice::Sz,
            container: vec![0; stored],
            estimates: Estimates {
                br_sz: 0.0,
                br_zfp: 0.0,
                br_dct: 0.0,
                psnr_target: 0.0,
                eb_sz: 1.0,
                eb_zfp: 1.0,
                eb_dct: 1.0,
                br_pipe: [f64::INFINITY; MAX_COMPOSED],
                eb_pipe: [1.0; MAX_COMPOSED],
            },
            raw_bytes,
        };
        // Empty field: no division by zero.
        assert_eq!(mk(0, 8).bit_rate(), 0.0);
        // 4 values, 4 stored bytes -> 8 bits/value exactly.
        assert!((mk(16, 4).bit_rate() - 8.0).abs() < 1e-12);
        // Non-multiple-of-4 raw size must not floor the divisor:
        // 6 raw bytes = 1.5 values; 3 stored bytes = 24 bits -> 16 b/v.
        assert!((mk(6, 3).bit_rate() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn forced_raw_choice_roundtrips_exactly() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(29, 3, 0);
        let cont = sel.compress_forced(&f, 1e-3, Choice::Raw).unwrap();
        assert_eq!(cont[0], Choice::Raw.id());
        assert_eq!(cont.len(), 1 + f.raw_bytes());
        let recon = sel.decompress(&cont).unwrap();
        assert_eq!(recon, f.data);
    }

    #[test]
    fn corrupt_selection_bit_rejected() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(23, 0, 0);
        let mut out = sel.compress(&f, 1e-3).unwrap();
        // 0xEE is far past every registered id (bare codecs 0–3 and
        // the built-in composed pipelines 4–8 are all valid now).
        out.container[0] = 0xEE;
        assert!(sel.decompress(&out.container).is_err());
        assert!(sel.decompress(&[]).is_err());
    }
}
