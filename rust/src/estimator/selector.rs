//! Algorithm 1: the automatic online selection method, generalized
//! from the paper's SZ-vs-ZFP decision to a multi-way ranking over the
//! registered candidate codecs (SZ, ZFP, DCT — §7 extension).
//!
//! Per field: estimate ZFP's bit-rate and PSNR from the sample (ZFP
//! anchors the iso-distortion target because its PSNR is data-driven);
//! derive the SZ quantization bin size δ and the DCT coefficient bin
//! size δ_c that match that PSNR (Eq. 10 inversion, Theorem 3);
//! estimate every candidate's bit-rate at its iso-PSNR operating
//! point; pick the candidate with the smallest estimated bit-rate;
//! compress. The output carries the selection bit s_i (paper's output
//! format) plus the estimates for observability.

use super::sampling::{sample_blocks, DEFAULT_RSP};
use super::{dct_model, sz_model, zfp_model};
use crate::codec_api::CodecRegistry;
use crate::data::field::{Dims, Field};
use crate::dct::compressor::coeff_delta;
use crate::dct::DctConfig;
use crate::sz::SzConfig;
use crate::zfp::block::block_size;
use crate::zfp::ZfpConfig;
use crate::{Error, Result};

// `Choice` is now a thin wrapper over codec-registry ids; re-exported
// here so `estimator::selector::Choice` keeps working.
pub use crate::codec_api::Choice;

/// Which codecs compete in the ranking. `Raw` never competes — it is
/// the no-compression policy, not a rate-distortion candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandidateSet {
    pub sz: bool,
    pub zfp: bool,
    pub dct: bool,
}

impl Default for CandidateSet {
    fn default() -> Self {
        CandidateSet::all()
    }
}

impl CandidateSet {
    /// Every registered rate-distortion codec (the default).
    pub const fn all() -> Self {
        CandidateSet { sz: true, zfp: true, dct: true }
    }

    /// The paper's original Algorithm 1 matrix (SZ vs ZFP) — used by
    /// the Table 2–5 / Fig. 6–9 reproductions for fidelity.
    pub const fn two_way() -> Self {
        CandidateSet { sz: true, zfp: true, dct: false }
    }

    /// Parse a comma-separated codec list, e.g. `"sz,zfp,dct"`.
    /// Empty tokens (trailing commas) are ignored; an entirely empty
    /// list is an error.
    pub fn parse(s: &str) -> Result<Self> {
        let mut set = CandidateSet { sz: false, zfp: false, dct: false };
        for tok in s.split(',') {
            match tok.trim().to_ascii_lowercase().as_str() {
                "" => {}
                "sz" => set.sz = true,
                "zfp" => set.zfp = true,
                "dct" => set.dct = true,
                other => {
                    return Err(Error::InvalidArg(format!(
                        "unknown codec '{other}' (expected sz, zfp, dct)"
                    )))
                }
            }
        }
        if !(set.sz || set.zfp || set.dct) {
            return Err(Error::InvalidArg("empty codec set".into()));
        }
        Ok(set)
    }

    /// Enabled candidates in stable ranking order (ties resolve toward
    /// the earlier, longer-validated codec: SZ, then ZFP, then DCT).
    pub fn choices(self) -> impl Iterator<Item = Choice> {
        [
            (self.sz, Choice::Sz),
            (self.zfp, Choice::Zfp),
            (self.dct, Choice::Dct),
        ]
        .into_iter()
        .filter_map(|(on, c)| on.then_some(c))
    }

    /// `true` if `choice` competes in this set.
    pub fn contains(self, choice: Choice) -> bool {
        match choice {
            Choice::Sz => self.sz,
            Choice::Zfp => self.zfp,
            Choice::Dct => self.dct,
            Choice::Raw => false,
        }
    }

    /// Comma-separated names of the enabled candidates.
    pub fn names(self) -> String {
        self.choices().map(|c| c.name()).collect::<Vec<_>>().join(",")
    }

    /// Rank: smallest estimated bit-rate wins; strict `<` so ties keep
    /// the earliest candidate in [`CandidateSet::choices`] order. NaN
    /// estimates never win.
    pub fn rank(self, est: &Estimates) -> Result<Choice> {
        let mut best: Option<(Choice, f64)> = None;
        for c in self.choices() {
            let br = est.bit_rate_of(c);
            if br.is_nan() {
                continue;
            }
            let better = match best {
                None => true,
                Some((_, b)) => br < b,
            };
            if better {
                best = Some((c, br));
            }
        }
        best.map(|(c, _)| c).ok_or_else(|| {
            Error::InvalidArg("no rankable codec candidate (empty set or NaN estimates)".into())
        })
    }
}

/// Selector configuration.
#[derive(Clone, Copy, Debug)]
pub struct SelectorConfig {
    /// Stage-I blockwise sampling rate r_sp.
    pub r_sp: f64,
    /// SZ quantization capacity.
    pub capacity: u32,
    pub sz: SzConfig,
    pub zfp: ZfpConfig,
    pub dct: DctConfig,
    pub zfp_model: zfp_model::ZfpModelConfig,
    /// Codecs competing in the ranking (default: SZ, ZFP, DCT).
    pub candidates: CandidateSet,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            r_sp: DEFAULT_RSP,
            capacity: 65_535,
            sz: SzConfig::default(),
            zfp: ZfpConfig::default(),
            dct: DctConfig::default(),
            zfp_model: zfp_model::ZfpModelConfig::default(),
            candidates: CandidateSet::all(),
        }
    }
}

/// Estimates computed by Algorithm 1 (lines 5–9), one column per
/// candidate codec.
#[derive(Clone, Copy, Debug)]
pub struct Estimates {
    pub br_sz: f64,
    pub br_zfp: f64,
    pub br_dct: f64,
    /// The iso-distortion target PSNR (ZFP's estimated PSNR).
    pub psnr_target: f64,
    /// Absolute error bound handed to SZ (δ/2, ≤ the user bound).
    pub eb_sz: f64,
    /// Absolute error bound handed to ZFP (the user bound).
    pub eb_zfp: f64,
    /// Absolute pointwise bound handed to DCT (≤ the user bound; the
    /// codec derives its own coefficient bin size δ_c from it).
    pub eb_dct: f64,
}

impl Estimates {
    /// The bound Algorithm 1 hands to `choice`'s codec: SZ and DCT get
    /// their iso-PSNR bounds, every other codec the user bound.
    pub fn bound_for(&self, choice: Choice) -> f64 {
        match choice {
            Choice::Sz => self.eb_sz,
            Choice::Dct => self.eb_dct,
            _ => self.eb_zfp,
        }
    }

    /// Estimated bit-rate of one candidate.
    pub fn bit_rate_of(&self, choice: Choice) -> f64 {
        match choice {
            Choice::Sz => self.br_sz,
            Choice::Zfp => self.br_zfp,
            Choice::Dct => self.br_dct,
            Choice::Raw => 32.0,
        }
    }
}

/// Result of selection + compression for one field.
#[derive(Clone, Debug)]
pub struct CompressOutput {
    pub choice: Choice,
    /// Self-describing payload: selection byte + codec stream.
    pub container: Vec<u8>,
    pub estimates: Estimates,
    /// Uncompressed size in bytes.
    pub raw_bytes: usize,
}

impl CompressOutput {
    /// Achieved compression ratio.
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.container.len() as f64
    }

    /// Achieved bit-rate (bits/value, f32 input). Computed in f64 so
    /// non-multiple-of-4 sizes don't floor; 0.0 for an empty field.
    pub fn bit_rate(&self) -> f64 {
        if self.raw_bytes == 0 {
            return 0.0;
        }
        self.container.len() as f64 * 8.0 / (self.raw_bytes as f64 / 4.0)
    }
}

/// The automatic online selector (Algorithm 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct AutoSelector {
    pub cfg: SelectorConfig,
}

impl AutoSelector {
    pub fn new(cfg: SelectorConfig) -> Self {
        AutoSelector { cfg }
    }

    /// The codec registry for this selector's configuration — the one
    /// place that maps selection bytes to concrete codecs.
    pub fn registry(&self) -> CodecRegistry {
        CodecRegistry::standard(self.cfg.sz, self.cfg.zfp, self.cfg.dct)
    }

    /// Algorithm 1 lines 2–10, multi-way: estimate every candidate at
    /// the shared target PSNR and choose. `eb_rel` is the
    /// value-range-based relative error bound; the absolute bound is
    /// eb = eb_rel · VR (line 2).
    pub fn select(&self, field: &Field, eb_rel: f64) -> Result<(Choice, Estimates)> {
        let vr = field.value_range();
        let eb = self.absolute_bound(vr, eb_rel)?;
        self.select_abs(field, eb, vr)
    }

    /// Selection with an explicit absolute bound.
    pub fn select_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<(Choice, Estimates)> {
        let est = self.estimate_abs(field, eb, vr)?;
        let choice = self.cfg.candidates.rank(&est)?;
        Ok((choice, est))
    }

    /// Estimate every candidate's iso-PSNR operating point and
    /// bit-rate (Algorithm 1 lines 3–9, one column per codec). Split
    /// from [`Self::select_abs`] so chunked runs can compute one
    /// field-level estimate and share it across chunks (DESIGN.md §11).
    pub fn estimate_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<Estimates> {
        if eb <= 0.0 || !eb.is_finite() {
            return Err(Error::InvalidArg(format!("bad error bound {eb}")));
        }
        // Line 3–4: blockwise + pointwise sampling.
        let sample = sample_blocks(field.dims, self.cfg.r_sp);

        // Lines 5–6: ZFP bit-rate (n̄_sb) and PSNR (PSNR_sp). ZFP is
        // always modeled — even when not a candidate — because its
        // data-driven PSNR anchors the iso-distortion target.
        let zfp_est =
            zfp_model::estimate(&field.data, field.dims, &sample, eb, vr, self.cfg.zfp_model);

        // Line 7: derive SZ's bin size from PSNR_sz := PSNR_zfp.
        // Clamp so SZ's pointwise bound never exceeds the user's bound
        // (ZFP over-preserves error, so normally δ/2 < eb already).
        let delta = if zfp_est.psnr.is_finite() && vr > 0.0 {
            sz_model::delta_from_psnr(zfp_est.psnr, vr).min(2.0 * eb)
        } else {
            2.0 * eb
        };
        let delta = if delta > 0.0 { delta } else { 2.0 * eb };

        // Lines 8–9: SZ PDF + bit-rate at that δ.
        let sz_est =
            sz_model::estimate(&field.data, field.dims, &sample, delta, self.cfg.capacity, vr);

        // DCT quantizes coefficients; Theorem 3 keeps MSE equal across
        // the orthogonal transform, so the iso-PSNR bin size δ applies
        // to the coefficient quantizer directly. Cap it at the
        // coefficient delta of the user bound so the pointwise
        // guarantee never loosens.
        let ndim = field.dims.ndim();
        let delta_dct = delta.min(coeff_delta(eb, ndim));
        let dct_est = if self.cfg.candidates.dct {
            dct_model::estimate(
                &field.data,
                field.dims,
                &sample,
                delta_dct,
                self.cfg.capacity,
                field.len(),
                vr,
            )
            .bit_rate
        } else {
            f64::INFINITY
        };

        Ok(Estimates {
            br_sz: sz_est.bit_rate,
            br_zfp: zfp_est.bit_rate,
            br_dct: dct_est,
            psnr_target: zfp_est.psnr,
            eb_sz: delta / 2.0,
            eb_zfp: eb,
            // The DCT codec takes a *pointwise* bound and derives its
            // own coefficient bin size; invert `coeff_delta`.
            eb_dct: delta_dct * (block_size(ndim) as f64).sqrt() / 2.0,
        })
    }

    /// Full Algorithm 1: select, then compress with the chosen codec
    /// (lines 10–15). Output container = selection byte + codec stream.
    pub fn compress(&self, field: &Field, eb_rel: f64) -> Result<CompressOutput> {
        let vr = field.value_range();
        let eb = self.absolute_bound(vr, eb_rel)?;
        self.compress_abs(field, eb, vr)
    }

    /// Compression with an explicit absolute bound.
    pub fn compress_abs(&self, field: &Field, eb: f64, vr: f64) -> Result<CompressOutput> {
        let (choice, estimates) = self.select_abs(field, eb, vr)?;
        // Paper output format: selection bit s_i + codec stream — the
        // registry frames both.
        let container =
            self.registry().encode(choice, &field.data, field.dims, estimates.bound_for(choice))?;
        Ok(CompressOutput { choice, container, estimates, raw_bytes: field.raw_bytes() })
    }

    /// Compress with a *forced* codec (baseline policies / Fig. 7 bars).
    pub fn compress_forced(&self, field: &Field, eb: f64, choice: Choice) -> Result<Vec<u8>> {
        self.registry().encode(choice, &field.data, field.dims, eb)
    }

    /// Decompress a container produced by [`Self::compress`].
    pub fn decompress(&self, container: &[u8]) -> Result<Vec<f32>> {
        let (data, _dims) = self.decompress_with_dims(container)?;
        Ok(data)
    }

    /// Decompress, returning dims too. Dispatches on the leading
    /// selection byte through the codec registry.
    pub fn decompress_with_dims(&self, container: &[u8]) -> Result<(Vec<f32>, Dims)> {
        self.registry().decode(container)
    }

    fn absolute_bound(&self, vr: f64, eb_rel: f64) -> Result<f64> {
        if eb_rel <= 0.0 || !eb_rel.is_finite() {
            return Err(Error::InvalidArg(format!("bad relative bound {eb_rel}")));
        }
        // Constant fields have VR = 0; any tiny positive bound works.
        Ok(if vr > 0.0 { eb_rel * vr } else { eb_rel })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{atm, hurricane};
    use crate::metrics::error_stats;

    #[test]
    fn compress_roundtrip_respects_bound() {
        let sel = AutoSelector::default();
        for idx in [0usize, 4, 8] {
            let f = atm::generate_field_scaled(7, idx, 0);
            let vr = f.value_range();
            let out = sel.compress(&f, 1e-3).unwrap();
            let recon = sel.decompress(&out.container).unwrap();
            let stats = error_stats(&f.data, &recon);
            assert!(
                stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6),
                "field {idx} ({:?}): err {} bound {}",
                out.choice,
                stats.max_abs_err,
                1e-3 * vr
            );
        }
    }

    #[test]
    fn smooth_fields_pick_sz_rough_pick_zfp() {
        // The paper's original two-way matrix (Algorithm 1 as
        // published); DCT is excluded so the assertion stays the
        // SZ-vs-ZFP decision the paper validates.
        let cfg = SelectorConfig { candidates: CandidateSet::two_way(), ..Default::default() };
        let sel = AutoSelector::new(cfg);
        // idx 0 is a Smooth class (SZ-friendly); idx 7 is Rough.
        let smooth = atm::generate_field_scaled(11, 0, 1);
        let rough = atm::generate_field_scaled(11, 7, 1);
        let (cs, es) = sel.select(&smooth, 1e-4).unwrap();
        let (cr, er) = sel.select(&rough, 1e-4).unwrap();
        assert_eq!(cs, Choice::Sz, "smooth: {es:?}");
        assert_eq!(cr, Choice::Zfp, "rough: {er:?}");
    }

    #[test]
    fn three_way_pick_has_smallest_estimated_bitrate() {
        let sel = AutoSelector::default();
        for idx in [0usize, 3, 7] {
            let f = atm::generate_field_scaled(11, idx, 0);
            let (choice, est) = sel.select(&f, 1e-4).unwrap();
            let best = est.br_sz.min(est.br_zfp).min(est.br_dct);
            assert_eq!(est.bit_rate_of(choice), best, "idx {idx}: {est:?}");
        }
    }

    #[test]
    fn candidate_set_parse_and_rank() {
        assert_eq!(CandidateSet::parse("sz,zfp,dct").unwrap(), CandidateSet::all());
        assert_eq!(CandidateSet::parse("SZ , ZFP").unwrap(), CandidateSet::two_way());
        // Trailing commas are tolerated; empty lists are not.
        assert_eq!(CandidateSet::parse("sz,zfp,").unwrap(), CandidateSet::two_way());
        assert!(CandidateSet::parse("zstd").is_err());
        assert!(CandidateSet::parse("").is_err());
        assert!(CandidateSet::parse(",").is_err());
        let est = Estimates {
            br_sz: 2.0,
            br_zfp: 2.0,
            br_dct: 1.0,
            psnr_target: 60.0,
            eb_sz: 1.0,
            eb_zfp: 1.0,
            eb_dct: 1.0,
        };
        // Smallest BR wins; ties keep the earlier candidate.
        assert_eq!(CandidateSet::all().rank(&est).unwrap(), Choice::Dct);
        assert_eq!(CandidateSet::two_way().rank(&est).unwrap(), Choice::Sz);
        assert_eq!(CandidateSet::parse("dct").unwrap().names(), "DCT");
        assert!(CandidateSet::all().contains(Choice::Dct));
        assert!(!CandidateSet::all().contains(Choice::Raw));
    }

    #[test]
    fn dct_only_candidates_select_and_roundtrip() {
        let cfg = SelectorConfig {
            candidates: CandidateSet::parse("dct").unwrap(),
            ..Default::default()
        };
        let sel = AutoSelector::new(cfg);
        let f = atm::generate_field_scaled(41, 2, 0);
        let vr = f.value_range();
        let out = sel.compress(&f, 1e-3).unwrap();
        assert_eq!(out.choice, Choice::Dct);
        assert_eq!(out.container[0], Choice::Dct.id());
        let recon = sel.decompress(&out.container).unwrap();
        let stats = error_stats(&f.data, &recon);
        assert!(
            stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6),
            "err {} bound {}",
            stats.max_abs_err,
            1e-3 * vr
        );
    }

    #[test]
    fn selection_bit_matches_choice() {
        let sel = AutoSelector::default();
        let f = hurricane::generate_field_scaled(3, 0, 0);
        let out = sel.compress(&f, 1e-3).unwrap();
        assert_eq!(out.container[0], out.choice.id());
    }

    #[test]
    fn iso_psnr_sz_bound_not_looser_than_user() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(13, 2, 0);
        let vr = f.value_range();
        let (_, est) = sel.select(&f, 1e-4).unwrap();
        assert!(est.eb_sz <= est.eb_zfp * (1.0 + 1e-12));
        assert!(est.eb_dct <= est.eb_zfp * (1.0 + 1e-12));
        assert!(est.eb_zfp > 0.0 && (est.eb_zfp - 1e-4 * vr).abs() < 1e-12 * vr);
    }

    #[test]
    fn constant_field_handled() {
        let f = Field::new("const", Dims::D2(64, 64), vec![2.5; 4096]);
        let sel = AutoSelector::default();
        let out = sel.compress(&f, 1e-4).unwrap();
        let recon = sel.decompress(&out.container).unwrap();
        assert!(recon.iter().all(|&v| (v - 2.5).abs() <= 1e-4));
        // A single-symbol Huffman stream costs 1 bit/value → ratio ≈ 32
        // minus header overhead (SZ-1.4 behaves the same without gzip).
        assert!(out.ratio() > 25.0, "constant field ratio {}", out.ratio());
    }

    #[test]
    fn forced_choice_roundtrip() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(17, 1, 0);
        let vr = f.value_range();
        for c in [Choice::Sz, Choice::Zfp, Choice::Dct] {
            let cont = sel.compress_forced(&f, 1e-3 * vr, c).unwrap();
            assert_eq!(cont[0], c.id());
            let recon = sel.decompress(&cont).unwrap();
            let stats = error_stats(&f.data, &recon);
            assert!(stats.max_abs_err <= 1e-3 * vr * (1.0 + 1e-6), "{c:?}");
        }
    }

    #[test]
    fn rejects_bad_bounds() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(19, 0, 0);
        assert!(sel.compress(&f, 0.0).is_err());
        assert!(sel.compress(&f, -1.0).is_err());
        assert!(sel.compress(&f, f64::NAN).is_err());
    }

    #[test]
    fn bit_rate_guards_empty_and_fractional_sizes() {
        let mk = |raw_bytes: usize, stored: usize| CompressOutput {
            choice: Choice::Sz,
            container: vec![0; stored],
            estimates: Estimates {
                br_sz: 0.0,
                br_zfp: 0.0,
                br_dct: 0.0,
                psnr_target: 0.0,
                eb_sz: 1.0,
                eb_zfp: 1.0,
                eb_dct: 1.0,
            },
            raw_bytes,
        };
        // Empty field: no division by zero.
        assert_eq!(mk(0, 8).bit_rate(), 0.0);
        // 4 values, 4 stored bytes -> 8 bits/value exactly.
        assert!((mk(16, 4).bit_rate() - 8.0).abs() < 1e-12);
        // Non-multiple-of-4 raw size must not floor the divisor:
        // 6 raw bytes = 1.5 values; 3 stored bytes = 24 bits -> 16 b/v.
        assert!((mk(6, 3).bit_rate() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn forced_raw_choice_roundtrips_exactly() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(29, 3, 0);
        let cont = sel.compress_forced(&f, 1e-3, Choice::Raw).unwrap();
        assert_eq!(cont[0], Choice::Raw.id());
        assert_eq!(cont.len(), 1 + f.raw_bytes());
        let recon = sel.decompress(&cont).unwrap();
        assert_eq!(recon, f.data);
    }

    #[test]
    fn corrupt_selection_bit_rejected() {
        let sel = AutoSelector::default();
        let f = atm::generate_field_scaled(23, 0, 0);
        let mut out = sel.compress(&f, 1e-3).unwrap();
        out.container[0] = 7;
        assert!(sel.decompress(&out.container).is_err());
        assert!(sel.decompress(&[]).is_err());
    }
}
