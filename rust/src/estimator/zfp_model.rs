//! ZFP compression-quality model (paper §5.2).
//!
//! **Bit-rate** (§5.2.1): per sampled block, run Stage I only (exponent
//! alignment → fixed point → lifted transform → sequency order →
//! negabinary), count significant bits n_sb at the EC-subsampled
//! coefficient ranks, linearly interpolate the staircase across the
//! remaining ranks, and average. A small analytic term adds the
//! embedded coder's framing cost (per-plane group tests + first-
//! significance scans + block headers).
//!
//! **PSNR** (§5.2.2): truncation error of the sampled coefficients
//! (dropped low bit-planes), scaled by the block's exponent offset.
//! We additionally correct for the lifted transform's inverse gain
//! (zfp's transform is *scaled* non-orthonormal: truncation error grows
//! by ≈√4.0625 per axis through the inverse transform — this is exactly
//! why zfp reserves 2·(d+1) guard bit-planes). The correction is
//! ablatable (`gain_correction` flag) to reproduce the paper's plain
//! estimator.

use super::sampling::{ec_sample_ranks, BlockSample};
use crate::data::field::Dims;
use crate::metrics::psnr_from_mse;
use crate::zfp::block::{self, block_size};
use crate::zfp::compressor::{block_precision, min_exp_from_tolerance};
use crate::zfp::fixedpoint::{self, INTPREC};
use crate::zfp::transform;

/// Per-value MSE amplification of the inverse lifted transform per
/// axis: mean squared column norm of T⁻¹ = (4+5+4+3.25)/4.
pub const INV_GAIN_PER_AXIS: f64 = 4.0625;

/// A ZFP quality estimate.
#[derive(Clone, Copy, Debug)]
pub struct ZfpEstimate {
    /// Estimated bits/value.
    pub bit_rate: f64,
    /// Estimated PSNR (dB).
    pub psnr: f64,
    /// Mean significant bits per coefficient (n̄_sb, before framing).
    pub mean_nsb: f64,
}

/// How the per-block bit cost is estimated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitRateMode {
    /// Exact embedded-coding cost of each sampled block (one counting
    /// pass over coefficients already in hand — same O(r_sp·N) class,
    /// strictly more accurate; our default).
    ExactEc,
    /// The paper's §5.2.1 method: n_sb at the EC-subsampled ranks +
    /// staircase interpolation + analytic framing. Kept for the
    /// `ablation` bench.
    Staircase,
}

/// Configuration of the ZFP estimator.
#[derive(Clone, Copy, Debug)]
pub struct ZfpModelConfig {
    /// Apply the inverse-transform gain correction to the MSE estimate.
    pub gain_correction: bool,
    /// zfp maxprec (mirrors the codec config).
    pub max_prec: u32,
    /// Bit-rate estimation mode.
    pub bit_rate_mode: BitRateMode,
}

impl Default for ZfpModelConfig {
    fn default() -> Self {
        ZfpModelConfig {
            gain_correction: true,
            max_prec: INTPREC,
            bit_rate_mode: BitRateMode::ExactEc,
        }
    }
}

/// Significant bits of a negabinary coefficient above plane `kmin`.
#[inline]
fn n_sb(u: u32, kmin: u32) -> f64 {
    if u == 0 {
        0.0
    } else {
        let msb = 31 - u.leading_zeros(); // position of top set bit
        (msb as i64 + 1 - kmin as i64).max(0) as f64
    }
}

/// Estimate ZFP quality for a field at an absolute tolerance.
pub fn estimate(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    tolerance: f64,
    value_range: f64,
    cfg: ZfpModelConfig,
) -> ZfpEstimate {
    let ndim = dims.ndim();
    let bs = block_size(ndim);
    let min_exp = min_exp_from_tolerance(tolerance);
    let perm = block::sequency_perm(ndim);
    let ranks = ec_sample_ranks(ndim);

    let mut fblock = vec![0.0f32; bs];
    let mut iblock = vec![0i32; bs];
    let mut ublock = vec![0u32; bs];

    let mut total_bits = 0.0f64; // n_sb payload bits over all ranks
    let mut frame_bits = 0.0f64; // headers + EC framing
    let mut err_sq_sum = 0.0f64; // truncation error accumulator
    let mut err_samples = 0usize;

    for &coords in &sample.blocks {
        block::gather(data, dims, coords, &mut fblock);
        let e_max = fixedpoint::max_exponent(&fblock);
        let prec = e_max
            .map(|e| block_precision(e, cfg.max_prec, min_exp, ndim))
            .unwrap_or(0);
        if prec == 0 {
            frame_bits += 1.0; // empty-block flag
            err_samples += ranks.len(); // zero error contributions
            continue;
        }
        let e_max = e_max.unwrap();
        let kmin = INTPREC.saturating_sub(prec);

        fixedpoint::to_fixed(&fblock, e_max, &mut iblock);
        transform::forward_block(&mut iblock, ndim);
        for (rank, &lin) in perm.iter().enumerate() {
            ublock[rank] = fixedpoint::int2uint(iblock[lin]);
        }

        // --- bit-rate.
        let sampled: Vec<(usize, f64)> =
            ranks.iter().map(|&r| (r, n_sb(ublock[r], kmin))).collect();
        match cfg.bit_rate_mode {
            BitRateMode::ExactEc => {
                total_bits += crate::zfp::embedded::encode_cost(&ublock[..bs], kmin) as f64;
                frame_bits += 1.0 + 9.0;
            }
            BitRateMode::Staircase => {
                let mut block_bits = 0.0;
                for w in sampled.windows(2) {
                    let (r0, v0) = w[0];
                    let (r1, v1) = w[1];
                    let span = (r1 - r0) as f64;
                    // Trapezoidal sum of the interpolated staircase over
                    // ranks r0..r1 (last rank added below).
                    block_bits += (0..(r1 - r0))
                        .map(|i| v0 + (v1 - v0) * i as f64 / span)
                        .sum::<f64>();
                }
                block_bits += sampled.last().unwrap().1;
                total_bits += block_bits;
                // Analytic framing: one group test per encoded plane +
                // one scan bit per coefficient + header.
                let planes = (INTPREC - kmin) as f64;
                frame_bits += 1.0 + 9.0 + planes + bs as f64;
            }
        }

        // --- PSNR: truncation error of sampled coefficients.
        let scale = fixedpoint::exp2_f64(e_max - (INTPREC as i32 - 2));
        let mask: u32 = if kmin == 0 { 0 } else { (1u32 << kmin) - 1 };
        for &(r, _) in &sampled {
            let u = ublock[r];
            let dropped =
                fixedpoint::uint2int(u) as i64 - fixedpoint::uint2int(u & !mask) as i64;
            let e = dropped as f64 * scale;
            err_sq_sum += e * e;
            err_samples += 1;
        }
    }

    // Normalize by the number of *real* data points the sampled blocks
    // represent: the codec pays for padded edge blocks but reports
    // bits per actual value (a ~17% effect on e.g. 25×125×125 grids).
    let real_points_per_block = data.len() as f64 / sample.total_blocks as f64;
    let n_points = sample.blocks.len() as f64 * real_points_per_block;
    let mean_nsb = total_bits / (sample.blocks.len() * bs) as f64;
    let bit_rate = (total_bits + frame_bits) / n_points;

    let mut mse = if err_samples > 0 { err_sq_sum / err_samples as f64 } else { 0.0 };
    if cfg.gain_correction {
        mse *= INV_GAIN_PER_AXIS.powi(ndim as i32);
    }
    let psnr = psnr_from_mse(mse, value_range);

    ZfpEstimate { bit_rate, psnr, mean_nsb }
}

/// Ablation variant: run the real embedded coder on the sampled blocks
/// and measure exact bits (higher overhead, exact sampled bit-rate).
pub fn estimate_exact_ec(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    tolerance: f64,
) -> f64 {
    use crate::codec::BitWriter;
    let ndim = dims.ndim();
    let bs = block_size(ndim);
    let min_exp = min_exp_from_tolerance(tolerance);
    let perm = block::sequency_perm(ndim);
    let mut fblock = vec![0.0f32; bs];
    let mut iblock = vec![0i32; bs];
    let mut ublock = vec![0u32; bs];
    let mut w = BitWriter::new();
    for &coords in &sample.blocks {
        block::gather(data, dims, coords, &mut fblock);
        let e_max = fixedpoint::max_exponent(&fblock);
        let prec = e_max
            .map(|e| block_precision(e, INTPREC, min_exp, ndim))
            .unwrap_or(0);
        if prec == 0 {
            w.write_bit(false);
            continue;
        }
        let e_max = e_max.unwrap();
        w.write_bit(true);
        w.write_bits((e_max + 127) as u64, 9);
        fixedpoint::to_fixed(&fblock, e_max, &mut iblock);
        transform::forward_block(&mut iblock, ndim);
        for (rank, &lin) in perm.iter().enumerate() {
            ublock[rank] = fixedpoint::int2uint(iblock[lin]);
        }
        crate::zfp::embedded::encode_ints(&ublock, INTPREC - prec, &mut w);
    }
    let real_points_per_block = data.len() as f64 / sample.total_blocks as f64;
    w.bit_len() as f64 / (sample.blocks.len() as f64 * real_points_per_block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectral::{grf_2d, grf_3d};
    use crate::estimator::sampling::sample_blocks;
    use crate::metrics::{bit_rate, error_stats, value_range};
    use crate::testing::Rng;
    use crate::zfp::ZfpCompressor;

    fn check_field(data: &[f32], dims: Dims, eb_rel: f64, br_tol: f64, psnr_tol_db: f64) {
        let vr = value_range(data);
        let tol = eb_rel * vr;
        let sample = sample_blocks(dims, 0.05);
        let est = estimate(data, dims, &sample, tol, vr, ZfpModelConfig::default());

        let zfp = ZfpCompressor::default();
        let comp = zfp.compress(data, dims, tol).unwrap();
        let (recon, _) = zfp.decompress(&comp).unwrap();
        let real_br = bit_rate(comp.len(), data.len());
        let real = error_stats(data, &recon);

        let rel_br = (est.bit_rate - real_br) / real_br;
        assert!(
            rel_br.abs() < br_tol,
            "BR est {:.3} vs real {real_br:.3} (rel {rel_br:+.3})",
            est.bit_rate
        );
        assert!(
            (est.psnr - real.psnr).abs() < psnr_tol_db,
            "PSNR est {:.2} vs real {:.2}",
            est.psnr,
            real.psnr
        );
    }

    #[test]
    fn estimate_tracks_real_zfp_2d() {
        let mut rng = Rng::new(151);
        let f = grf_2d(&mut rng, 160, 160, 2.5);
        check_field(&f, Dims::D2(160, 160), 1e-3, 0.30, 6.0);
    }

    #[test]
    fn estimate_tracks_real_zfp_3d() {
        let mut rng = Rng::new(152);
        let f = grf_3d(&mut rng, 40, 40, 40, 2.2);
        check_field(&f, Dims::D3(40, 40, 40), 1e-3, 0.30, 6.0);
    }

    #[test]
    fn rough_field_higher_bitrate_than_smooth() {
        let mut rng = Rng::new(153);
        let dims = Dims::D2(128, 128);
        let smooth = grf_2d(&mut rng, 128, 128, 3.5);
        let rough = grf_2d(&mut rng, 128, 128, 0.8);
        let vr_s = value_range(&smooth);
        let vr_r = value_range(&rough);
        let sample = sample_blocks(dims, 0.1);
        let cfg = ZfpModelConfig::default();
        let es = estimate(&smooth, dims, &sample, 1e-4 * vr_s, vr_s, cfg);
        let er = estimate(&rough, dims, &sample, 1e-4 * vr_r, vr_r, cfg);
        assert!(
            er.bit_rate > es.bit_rate,
            "rough {:.2} should exceed smooth {:.2}",
            er.bit_rate,
            es.bit_rate
        );
    }

    #[test]
    fn zero_field_low_bitrate() {
        let dims = Dims::D2(64, 64);
        let f = vec![0.0f32; dims.len()];
        let sample = sample_blocks(dims, 0.25);
        let est = estimate(&f, dims, &sample, 1e-4, 1.0, ZfpModelConfig::default());
        assert!(est.bit_rate < 0.2, "empty blocks ~1 bit: {}", est.bit_rate);
        assert!(est.psnr.is_infinite());
    }

    #[test]
    fn exact_ec_close_to_staircase_estimate() {
        let mut rng = Rng::new(154);
        let dims = Dims::D2(128, 128);
        let f = grf_2d(&mut rng, 128, 128, 2.0);
        let vr = value_range(&f);
        let sample = sample_blocks(dims, 0.2);
        let est = estimate(&f, dims, &sample, 1e-4 * vr, vr, ZfpModelConfig::default());
        let exact = estimate_exact_ec(&f, dims, &sample, 1e-4 * vr);
        let rel = (est.bit_rate - exact) / exact;
        assert!(rel.abs() < 0.35, "staircase {:.3} vs exact {exact:.3}", est.bit_rate);
    }

    #[test]
    fn nsb_helper() {
        assert_eq!(n_sb(0, 0), 0.0);
        assert_eq!(n_sb(1, 0), 1.0);
        assert_eq!(n_sb(0x8000_0000, 0), 32.0);
        assert_eq!(n_sb(0x8000_0000, 31), 1.0);
        assert_eq!(n_sb(0xF, 8), 0.0); // entirely below kmin
    }
}
