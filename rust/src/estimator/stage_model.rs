//! Sampled-statistics rate model for pipeline stages that have no
//! bespoke estimator (DESIGN.md §15) — the Black-Box Statistical
//! Prediction idea (arxiv 2305.08801): rank a coder from sampled
//! byte statistics instead of a hand-built model.
//!
//! Used for the lossless delta pipelines: the Lorenzo bit-pattern
//! residual of each sampled point is split into its four LE bytes and
//! the pipelines are priced from the pooled empirical byte
//! distribution. Both post-coders are order-0 (static Huffman, static
//! range coder), and order-0 coding is permutation-invariant — the
//! byte shuffle moves bytes around but cannot change a single-table
//! coder's rate — so one pooled entropy prices both chains; they
//! differ only in the coder's gap to the entropy bound. (A
//! context-modeling post-coder would exploit the shuffle's plane
//! grouping; when one lands, this model grows a per-plane column.)

use super::sampling::BlockSample;
use crate::data::field::Dims;
use crate::sz::lorenzo;

/// Range-coder gap to the entropy bound (bits/value, all four byte
/// planes together) — near zero by construction, kept non-zero so ties
/// break toward Huffman's simpler decode path.
const ARITH_GAP_BITS: f64 = 0.05;

/// Huffman gap over the four coded bytes of one value — the same
/// empirical constant the SZ model charges per coded stream.
const HUFF_GAP_BITS: f64 = 0.5;

/// Serialized table cost per distinct byte symbol (delta-varint symbol
/// + varint code length / frequency), matching
/// `sz_model::TABLE_BITS_PER_SYMBOL`.
const TABLE_BITS_PER_SYMBOL: f64 = 16.0;

/// Estimated bits/value for the two lossless delta pipelines.
#[derive(Clone, Copy, Debug)]
pub struct LosslessDeltaEstimate {
    /// `delta+shuffle+huff`: 4 × pooled byte entropy + Huffman gap +
    /// table.
    pub huff_bits: f64,
    /// `delta+arith`: 4 × pooled byte entropy + range-coder gap +
    /// table.
    pub arith_bits: f64,
}

/// Price the lossless delta pipelines from sampled byte statistics.
/// Residuals are the exact transform the `delta` stage applies —
/// wrapping bit-pattern subtraction against the Lorenzo prediction
/// from original neighbors — so the sampled distribution is the
/// coder's input distribution up to sampling noise (the byte alphabet
/// is capped at 256, which a few thousand samples observe well; no
/// richness extrapolation is needed).
pub fn estimate_lossless_delta(
    data: &[f32],
    dims: Dims,
    sample: &BlockSample,
    field_len: usize,
) -> LosslessDeltaEstimate {
    let idx = sample.point_indices();
    if idx.is_empty() || field_len == 0 {
        // No statistics: price as raw passthrough.
        return LosslessDeltaEstimate { huff_bits: 32.0, arith_bits: 32.0 };
    }
    let preds = lorenzo::predictions_original(data, dims, &idx);
    let mut counts = [0u64; 256];
    for (&i, p) in idx.iter().zip(&preds) {
        let dbits = data[i].to_bits().wrapping_sub(p.to_bits());
        for b in dbits.to_le_bytes() {
            counts[b as usize] += 1;
        }
    }
    let total = (idx.len() * 4) as f64;
    let mut h = 0.0;
    let mut occupied = 0usize;
    for &c in &counts {
        if c > 0 {
            let p = c as f64 / total;
            h -= p * p.log2();
            occupied += 1;
        }
    }
    let table = occupied as f64 * TABLE_BITS_PER_SYMBOL / field_len as f64;
    LosslessDeltaEstimate {
        huff_bits: 4.0 * h + HUFF_GAP_BITS + table,
        arith_bits: 4.0 * h + ARITH_GAP_BITS + table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::atm;
    use crate::estimator::sampling::sample_blocks;

    #[test]
    fn smooth_fields_price_below_raw() {
        let f = atm::generate_field_scaled(5, 0, 1); // Smooth class
        let sample = sample_blocks(f.dims, 0.05);
        let est = estimate_lossless_delta(&f.data, f.dims, &sample, f.len());
        assert!(
            est.huff_bits > 0.0 && est.huff_bits < 32.0,
            "huff {} should beat raw",
            est.huff_bits
        );
        // The range coder differs only by its smaller gap.
        assert!(est.arith_bits < est.huff_bits);
        assert!((est.huff_bits - est.arith_bits - (0.5 - 0.05)).abs() < 1e-9);
    }

    #[test]
    fn constant_field_prices_near_zero() {
        let f = crate::data::field::Field::new(
            "const",
            crate::data::field::Dims::D2(64, 64),
            vec![2.5f32; 4096],
        );
        let sample = sample_blocks(f.dims, 0.05);
        let est = estimate_lossless_delta(&f.data, f.dims, &sample, f.len());
        // All residual bytes are zero except the first point's: the
        // pooled distribution is (near-)single-symbol.
        assert!(est.huff_bits < 2.0, "constant field huff {}", est.huff_bits);
        assert!(est.arith_bits < 2.0, "constant field arith {}", est.arith_bits);
    }

    #[test]
    fn tracks_real_pipeline_size_on_smooth_field() {
        use crate::codec_api::{CodecRegistry, PIPE_DELTA_ARITH, PIPE_DELTA_HUFF};
        let f = atm::generate_field_scaled(5, 2, 0);
        let sample = sample_blocks(f.dims, 0.25);
        let est = estimate_lossless_delta(&f.data, f.dims, &sample, f.len());
        let r = CodecRegistry::default();
        for (id, est_bits) in [(PIPE_DELTA_HUFF, est.huff_bits), (PIPE_DELTA_ARITH, est.arith_bits)]
        {
            let stream = r.get(id).unwrap().compress(&f.data, f.dims, 1e-3).unwrap();
            let real = stream.len() as f64 * 8.0 / f.len() as f64;
            let rel = (est_bits - real) / real;
            assert!(rel.abs() < 0.5, "pipeline {id}: estimated {est_bits:.2} b/v vs real {real:.2} b/v");
        }
    }
}
