//! The `adaptivec` subcommands:
//!
//! * `compress`   — compress a dataset (or a raw f32 file) with a policy
//! * `decompress` — restore a container to raw f32 files
//! * `estimate`   — print Algorithm 1's estimates for every field
//! * `select`     — selection decisions only (Fig. 6-style map)
//! * `sweep`      — compression-ratio sweep over error bounds (Fig. 7)
//! * `iobench`    — modeled parallel store/load throughput (Figs. 8–9)
//! * `info`       — container summary (v1 and v2)
//! * `inspect`    — per-chunk selection map + per-codec byte totals

use super::args::Args;
use crate::baseline::Policy;
use crate::coordinator::store::ContainerReader;
use crate::data::{Dataset, Field};
use crate::engine::{Engine, EngineConfig, WritePlan};
use crate::estimator::selector::{AutoSelector, CandidateSet, Choice, SelectorConfig};
use crate::iosim::{FsModel, SvcModel, ThroughputModel, PROC_SWEEP};
use crate::service::net::{Client, ClientConfig, NetConfig, Server};
use crate::service::{ArchiveConfig, Service, ServiceConfig};
use crate::{Error, Result};
use std::sync::Arc;

pub const USAGE: &str = "adaptivec — online rate-distortion-optimal codec selection

USAGE:
  adaptivec <command> [options]

COMMANDS:
  compress    --dataset <nyx|atm|hurricane> [--scale 0|1|2] [--eb 1e-4]
              [--policy ours|sz|zfp|dct|eb|optimum|baseline] [--workers N]
              [--out FILE] [--seed N] [--rsp 0.05] [--chunk-elems N]
              [--codecs sz,zfp,dct] [--pipelines bitround+sz,delta+arith]
              [--chunk-prior N] [--prior-band B]
              [--write-plan single|two-pass] [--spill-mem BYTES]
              (--chunk-elems > 0 streams a chunked, seekable container
               straight to disk — the full payload is never held in
               memory. The default single-pass plan compresses each
               chunk exactly once, spilling payloads to scratch space
               until the index is written; --write-plan two-pass keeps
               the scratch-free protocol that compresses twice, and
               --spill-mem caps the in-memory scratch before a temp
               file is used. Chunks smaller than --chunk-prior (default
               65536 elems) share one field-level selection, larger
               chunks select independently — --chunk-prior 0 forces
               per-chunk selection everywhere; --prior-band > 0 lets a
               prior-covered chunk whose value range drifts past that
               relative band re-estimate itself (adaptive refresh);
               --codecs restricts the candidates the 'ours' policy
               ranks; --pipelines additionally admits composed staged
               pipelines — bitround+sz, bitround+zfp,
               bitround+sz+shuffle, delta+shuffle+huff, delta+arith —
               into the ranking alongside any bare codec names listed.
               The two flags share one grammar; pass only one of them)
  decompress  --in FILE [--outdir DIR] [--field NAME]
  estimate    --dataset D [--scale S] [--eb E] [--rsp 0.05] [--codecs C]
              [--pipelines P]
  select      --dataset D [--scale S] [--eb E] [--codecs C] [--pipelines P]
  sweep       --dataset D [--scale S] [--bounds 1e-3,1e-4,1e-6]
  iobench     --dataset D [--scale S] [--eb E]
  info        --in FILE
  inspect     --in FILE
  serve       [--addr 127.0.0.1:7845] [--workers N] [--queue-depth N]
              [--batch-max N] [--eb E] [--policy P] [--chunk-elems N]
              [--codecs C] [--pipelines P] [--archive-dir DIR]
              [--archive-mem BYTES] [--archive-readers N]
              [--read-timeout-ms MS] [--write-timeout-ms MS]
              [--idle-timeout-ms MS] [--max-conns N]
              [--conn-inflight-bytes BYTES]
              (concurrent service front end over one shared engine:
               bounded request queue with Busy admission control,
               batched store passes, length-prefixed TCP frames; runs
               until a client sends --op shutdown, then prints the
               final ServiceReport. On linux-64 the transport is a
               readiness-driven epoll reactor — nonblocking sockets,
               frame pipelining by correlation id, and backpressure
               instead of rejection: at --max-conns (default 4096) the
               server stops accepting and the backlog defers, and a
               connection past --conn-inflight-bytes (default 64 MiB)
               of admitted-but-unanswered request bytes stops being
               read until responses drain. ADAPTIVEC_NO_EPOLL=1 (or a
               non-linux target) falls back to one thread per
               connection with the same wire protocol. With
               --archive-dir the archive is persistent: batches past
               the --archive-mem hot budget (default 64 MiB) spill to
               sharded container files on a background spiller thread,
               cold fetches go through a bounded LRU of
               --archive-readers open readers (default 16), restart
               recovers the whole index from a shard scan, and
               shutdown flushes every still-hot batch. Without it the
               archive is in-memory only, as before. Timeouts guard
               the transport: a client stalled mid-frame past
               --read-timeout-ms (default 30000) is disconnected, an
               idle connection is closed after --idle-timeout-ms
               (default 300000); 0 disables a deadline)
  client      --op compress --dataset D [--scale S] [--seed N]
              [--retry-ms MS] [--retries N] [--pipeline N]
              | --op fetch --field NAME [--out FILE]
              | --op stats | --op shutdown
              [--addr 127.0.0.1:7845]
              [--timeout-ms MS] [--timeout-retries N]
              (drives a running `adaptivec serve`; compress retries
               Busy rejections with backoff and reports how many it
               absorbed; --pipeline N keeps up to N compress frames in
               flight on the one connection — responses are matched by
               correlation id, and pipelined runs do not retry Busy;
               deadline expiries on serial calls reconnect and retry
               up to --timeout-retries times)
";

fn selector_cfg(args: &Args) -> Result<SelectorConfig> {
    let r_sp = args.get_or("rsp", SelectorConfig::default().r_sp)?;
    let candidates = match (args.get("codecs"), args.get("pipelines")) {
        (Some(_), Some(_)) => {
            return Err(Error::InvalidArg(
                "use --codecs or --pipelines, not both (either flag accepts bare codec \
                 names and pipeline names alike)"
                    .into(),
            ))
        }
        (Some(list), None) | (None, Some(list)) => CandidateSet::parse(list)?,
        (None, None) => CandidateSet::all(),
    };
    Ok(SelectorConfig { r_sp, candidates, ..SelectorConfig::default() })
}

fn load_dataset(args: &Args) -> Result<Vec<Field>> {
    let name = args.require("dataset")?.to_string();
    let ds = Dataset::parse(&name)
        .ok_or_else(|| Error::InvalidArg(format!("unknown dataset '{name}'")))?;
    let scale: u8 = args.get_or("scale", 1)?;
    let seed: u64 = args.get_or("seed", 2018)?;
    Ok(ds.generate(seed, scale))
}

/// Entry point: dispatch a subcommand.
pub fn run(cmd: &str, argv: &[String]) -> Result<()> {
    match cmd {
        "compress" => cmd_compress(argv),
        "decompress" => cmd_decompress(argv),
        "estimate" => cmd_estimate(argv),
        "select" => cmd_select(argv),
        "sweep" => cmd_sweep(argv),
        "iobench" => cmd_iobench(argv),
        "info" => cmd_info(argv),
        "inspect" => cmd_inspect(argv),
        "serve" => cmd_serve(argv),
        "client" => cmd_client(argv),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::InvalidArg(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn cmd_compress(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let policy = Policy::parse(args.get("policy").unwrap_or("ours"))
        .ok_or_else(|| Error::InvalidArg("bad --policy".into()))?;
    let workers: usize = args.get_or("workers", 0)?;
    let out = args.get("out").unwrap_or("out.adaptivec").to_string();
    let chunk_elems: usize = args.get_or("chunk-elems", 0)?;
    let chunk_prior: usize =
        args.get_or("chunk-prior", crate::coordinator::DEFAULT_CHUNK_PRIOR_ELEMS)?;
    let write_plan = match args.get("write-plan") {
        None => WritePlan::default(),
        Some(s) => WritePlan::parse(s).ok_or_else(|| {
            Error::InvalidArg(format!("--write-plan: '{s}' (expected single or two-pass)"))
        })?,
    };
    let spill_mem: usize =
        args.get_or("spill-mem", crate::coordinator::spill::DEFAULT_SPILL_MEM_BUDGET)?;
    let prior_band: f64 = args.get_or("prior-band", 0.0)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;

    let mut ecfg = EngineConfig {
        selector_cfg: cfg,
        chunk_prior_elems: chunk_prior,
        write_plan,
        prior_drift_band: prior_band,
        ..EngineConfig::default()
    };
    if workers != 0 {
        ecfg.workers = workers;
    }
    ecfg.spill.mem_budget = spill_mem;
    let engine = Engine::new(ecfg);
    // Per-codec tallies resolve names through the engine's registry,
    // so every registered codec (including DCT, id 3) prints by name.
    let registry = engine.registry();
    let t0 = std::time::Instant::now();
    if chunk_elems > 0 {
        // Chunked v2 path, streamed: compressed chunks flow straight
        // into the output file through the index-first writer, so the
        // full payload is never resident (chunks below the prior
        // threshold still share a field-level selection, DESIGN.md §11).
        // Stream into a sibling temp file (pid-suffixed so concurrent
        // runs against the same --out cannot interleave) and rename on
        // success, so a mid-run failure can neither truncate a
        // pre-existing archive at `out` nor leave a half-written
        // container behind.
        let tmp_out = format!("{out}.{}.tmp", std::process::id());
        let sink = std::io::BufWriter::new(std::fs::File::create(&tmp_out)?);
        let (report, _) = match engine.compress_chunked_to(&fields, policy, eb, chunk_elems, sink)
        {
            Ok(v) => v,
            Err(e) => {
                std::fs::remove_file(&tmp_out).ok();
                return Err(e);
            }
        };
        if let Err(e) = std::fs::rename(&tmp_out, &out) {
            std::fs::remove_file(&tmp_out).ok();
            return Err(e.into());
        }
        let wall = t0.elapsed();
        let chunks = report.total_chunks();
        // The compression-work line is what the single-pass protocol
        // is for: each chunk's codec ran exactly once (vs twice under
        // two-pass), proven by the report's call counters.
        let work = match report.write_plan {
            WritePlan::SinglePassSpill => format!(
                "{} of {chunks} chunks compressed once (single-pass spill, peak scratch {} B{}{})",
                report.compress_calls.total(),
                report.peak_scratch_bytes,
                if report.scratch_spilled { ", spilled to temp file" } else { ", in memory" },
                if report.spliced_prefetched > 0 {
                    format!(", {} slabs splice-prefetched", report.spliced_prefetched)
                } else {
                    String::new()
                },
            ),
            WritePlan::TwoPassRecompress => format!(
                "{chunks} chunks compressed twice (two-pass recompress, {:.2}s regenerating)",
                report.recompress_time.as_secs_f64(),
            ),
        };
        let refresh_note = if prior_band > 0.0 {
            format!(", {} prior refreshes (band {prior_band})", report.prior_refreshes)
        } else {
            String::new()
        };
        println!(
            "{} fields / {chunks} chunks (streamed, {chunk_elems} elems/chunk), policy {}, \
             eb_rel {eb:.0e}: ratio {:.2} ({} -> {} bytes), picks {}, {work}{refresh_note}, \
             peak payload write buffer {} B vs {} B buffered ({:.1}%), wall {:.2}s -> {out}",
            report.fields.len(),
            policy.name(),
            report.overall_ratio(),
            report.total_raw_bytes(),
            report.total_stored_bytes(),
            report.codec_counts().summary(registry),
            report.peak_payload_bytes,
            report.total_stored_bytes(),
            report.peak_payload_frac() * 100.0,
            wall.as_secs_f64(),
        );
    } else {
        let report = engine.run(&fields, policy, eb)?;
        let wall = t0.elapsed();
        report.to_container().write_file(&out)?;
        println!(
            "{} fields, policy {}, eb_rel {eb:.0e}: ratio {:.2} ({} -> {} bytes), \
             picks {}, est-overhead {:.1}%, wall {:.2}s -> {out}",
            report.results.len(),
            policy.name(),
            report.overall_ratio(),
            report.total_raw_bytes(),
            report.total_stored_bytes(),
            report.codec_counts().summary(registry),
            report.overhead_frac() * 100.0,
            wall.as_secs_f64(),
        );
    }
    Ok(())
}

fn cmd_decompress(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let input = args.require("in")?.to_string();
    let outdir = args.get("outdir").unwrap_or(".").to_string();
    let field = args.get("field").map(str::to_string);
    args.check_unknown()?;
    // `open` parses only the index — chunk payloads are pread on
    // demand, a window of fields at a time, so peak memory is one
    // decode window, not the whole archive.
    let reader = ContainerReader::open(&input)?;
    let engine = Engine::default();
    std::fs::create_dir_all(&outdir)?;
    fn write_field(outdir: &str, f: &Field) -> Result<()> {
        use std::io::Write as _;
        let path = format!("{outdir}/{}.f32", f.name);
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        for v in &f.data {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }
    let mut restored = 0usize;
    match &field {
        // --field does a partial, index-driven decode of just that field.
        Some(name) => {
            write_field(&outdir, &engine.load_field(&reader, name)?)?;
            restored += 1;
        }
        None => engine.load_fields_streaming(&reader, |f| {
            write_field(&outdir, &f)?;
            restored += 1;
            Ok(())
        })?,
    }
    println!(
        "restored {restored} fields to {outdir}/ ({} index bytes read up front of {}-byte container)",
        reader.index_bytes(),
        reader.source_len()
    );
    Ok(())
}

fn cmd_estimate(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;
    let sel = AutoSelector::new(cfg);
    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>9} {:>10} {:>6}",
        "field", "BR_sz", "BR_zfp", "BR_dct", "BR_pipe", "PSNR_tgt", "pick"
    );
    for f in &fields {
        let (choice, est) = sel.select(f, eb)?;
        // A column is only an estimate when its candidate competes;
        // otherwise it is a sentinel (infinite), shown as "-".
        let fin = |v: f64| if v.is_finite() { format!("{v:.3}") } else { "-".into() };
        // Best composed-pipeline column (∞ when no pipeline competes).
        let br_pipe = cfg
            .candidates
            .pipelines
            .ids()
            .map(|id| est.bit_rate_of(Choice::Pipeline(id)))
            .fold(f64::INFINITY, f64::min);
        println!(
            "{:<22} {:>9.3} {:>9.3} {:>9} {:>9} {:>10.2} {:>6}",
            f.name,
            est.br_sz,
            est.br_zfp,
            fin(est.br_dct),
            fin(br_pipe),
            est.psnr_target,
            choice.name()
        );
    }
    Ok(())
}

fn cmd_select(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;
    let sel = AutoSelector::new(cfg);
    let mut counts: std::collections::BTreeMap<&'static str, usize> = Default::default();
    for f in &fields {
        let (choice, _) = sel.select(f, eb)?;
        *counts.entry(choice.name()).or_insert(0) += 1;
        println!("{:<22} -> {}", f.name, choice.name());
    }
    let summary: Vec<String> = counts
        .iter()
        .map(|(name, n)| {
            format!("{name} {n} ({:.1}%)", 100.0 * *n as f64 / fields.len() as f64)
        })
        .collect();
    println!("summary: {}", summary.join(", "));
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let bounds: Vec<f64> = args
        .get("bounds")
        .unwrap_or("1e-3,1e-4,1e-6")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|_| Error::InvalidArg(format!("bad bound {s}"))))
        .collect::<Result<_>>()?;
    args.check_unknown()?;
    let engine = Engine::default();
    println!("{:>8} {:>10} {:>10} {:>10} {:>10}", "eb_rel", "SZ", "ZFP", "ours", "optimum");
    for &eb in &bounds {
        let mut row = Vec::new();
        for p in [Policy::AlwaysSz, Policy::AlwaysZfp, Policy::RateDistortion, Policy::Optimum] {
            let report = engine.run(&fields, p, eb)?;
            row.push(report.overall_ratio());
        }
        println!(
            "{eb:>8.0e} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            row[0], row[1], row[2], row[3]
        );
    }
    Ok(())
}

fn cmd_iobench(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    args.check_unknown()?;
    let engine = Engine::default();
    let tm = ThroughputModel::new(FsModel::default());

    println!("store/load throughput model (GB/s of raw data), eb_rel {eb:.0e}");
    println!("{:>6} {:>10} {:>10} {:>10} {:>10}", "procs", "baseline", "SZ", "ZFP", "ours");
    let mut per_policy = Vec::new();
    for p in [Policy::NoCompression, Policy::AlwaysSz, Policy::AlwaysZfp, Policy::RateDistortion]
    {
        let report = engine.run(&fields, p, eb)?;
        let raw = report.total_raw_bytes() as f64;
        let stored = report.total_stored_bytes() as f64;
        let comp_t = report.total_compress_time().as_secs_f64()
            + report.total_estimate_time().as_secs_f64();
        per_policy.push((p, raw, stored, comp_t));
    }
    for &p in &PROC_SWEEP {
        print!("{p:>6}");
        for &(_, raw, stored, comp_t) in &per_policy {
            let tput = tm.store_throughput(p, raw, stored, comp_t);
            print!(" {:>10.2}", tput / 1e9);
        }
        println!();
    }

    // Streamed-write protocol comparison (modeled): the single-pass
    // spill plan pays a scratch round-trip over the *compressed*
    // bytes (slab-granular reads — one positioned read per chunk, as
    // the splice visits completion-order slabs in declared order);
    // two-pass re-runs compression over the raw bytes. Compression
    // time is the measured RateDistortion figure; slab count is one
    // per field at this whole-field granularity.
    let &(_, _, rd_stored, rd_comp) = per_policy
        .iter()
        .find(|(p, ..)| *p == Policy::RateDistortion)
        .expect("RateDistortion is in the policy sweep");
    let slabs = fields.len();
    println!(
        "\nstreamed write plans (modeled wall s/proc, 'ours' policy): {:>12} {:>12} {:>8}",
        "single-pass", "two-pass", "speedup"
    );
    for &p in &[1usize, 64, 1024] {
        let single = tm.fs.single_pass_store_time(p, rd_stored, slabs, rd_comp, 0.0);
        let two = tm.fs.two_pass_store_time(p, rd_stored, rd_comp);
        let label = format!("p={p}");
        println!(
            "{label:>58} {single:>12.3} {two:>12.3} {:>7.2}x",
            two / single.max(f64::MIN_POSITIVE)
        );
    }

    // Partial-load comparison (v2 index path): reconstructing one
    // field by slurping the whole container vs pread-ing only that
    // field's chunk ranges.
    let n = fields.len().max(1) as f64;
    let &(_, raw, stored, _) = per_policy
        .iter()
        .find(|(p, ..)| *p == Policy::RateDistortion)
        .expect("RateDistortion is in the policy sweep");
    println!(
        "\npartial load of 1/{} fields (modeled, GB/s of raw): {:>10} {:>10}",
        fields.len(),
        "slurp",
        "pread"
    );
    for &p in &[1usize, 64, 1024] {
        let slurp = tm.load_throughput(p, raw / n, stored, 0.0);
        let pread = tm.partial_load_throughput(p, raw / n, stored / n, 4, 0.0);
        let label = format!("p={p}");
        println!(
            "{label:>42} {:>10.2} {:>10.2}  ({:.1}x)",
            slurp / 1e9,
            pread / 1e9,
            pread / slurp.max(f64::MIN_POSITIVE)
        );
    }

    // Service batching model: per-pass dispatch overhead amortized
    // over the batch, against the measured per-field compression time
    // of the 'ours' policy — the knee the service_throughput bench
    // measures empirically.
    let svc = SvcModel::default();
    let per_req_raw = raw / n;
    let per_req_comp = rd_comp / n;
    println!(
        "\nservice batching model ('ours' policy, {:.1} KB/request): {:>12} {:>12}",
        per_req_raw / 1e3,
        "MB/s raw",
        "last-reply ms"
    );
    for &b in &[1usize, 4, 16] {
        let tput = svc.throughput(b, per_req_raw, per_req_comp);
        let lat = svc.batch_latency(b, per_req_comp);
        let label = format!("batch={b}");
        println!("{label:>56} {:>12.2} {:>12.3}", tput / 1e6, lat * 1e3);
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7845").to_string();
    let workers: usize = args.get_or("workers", 2)?;
    let queue_depth: usize = args.get_or("queue-depth", 64)?;
    let batch_max: usize = args.get_or("batch-max", 8)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let chunk_elems: usize = args.get_or("chunk-elems", 64 * 1024)?;
    let policy = Policy::parse(args.get("policy").unwrap_or("ours"))
        .ok_or_else(|| Error::InvalidArg("bad --policy".into()))?;
    // Archive persistence: without --archive-dir the archive stays in
    // memory (nothing spills, nothing survives a restart).
    let archive_dir = args.get("archive-dir").map(std::path::PathBuf::from);
    let archive_mem: usize = args.get_or("archive-mem", 64 << 20)?;
    let archive_readers: usize = args.get_or("archive-readers", 16)?;
    // Transport deadlines (0 = disabled): per-read/write socket
    // timeouts plus the idle budget for quiet connections.
    let read_timeout_ms: u64 = args.get_or("read-timeout-ms", 30_000)?;
    let write_timeout_ms: u64 = args.get_or("write-timeout-ms", 30_000)?;
    let idle_timeout_ms: u64 = args.get_or("idle-timeout-ms", 300_000)?;
    // Transport admission: at the connection cap the server stops
    // accepting (backlog defers, nothing is rejected); past the
    // per-connection in-flight byte budget the reactor stops reading
    // that connection until responses drain.
    let max_conns: usize = args.get_or("max-conns", 4096)?;
    let conn_inflight_bytes: usize = args.get_or("conn-inflight-bytes", 64 << 20)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;

    let engine = Arc::new(Engine::new(EngineConfig {
        selector_cfg: cfg,
        ..EngineConfig::default()
    }));
    let archive = ArchiveConfig {
        root_dir: archive_dir.clone(),
        mem_budget: archive_mem,
        open_readers: archive_readers,
        background_spill: true,
    };
    let svc = Service::start(
        engine,
        ServiceConfig {
            workers,
            queue_depth,
            batch_max,
            policy,
            eb_rel: eb,
            chunk_elems,
            archive,
            ..ServiceConfig::default()
        },
    )?;
    let recovered = svc.report().archive;
    let net = NetConfig {
        read_timeout: std::time::Duration::from_millis(read_timeout_ms),
        write_timeout: std::time::Duration::from_millis(write_timeout_ms),
        idle_timeout: std::time::Duration::from_millis(idle_timeout_ms),
        max_conns,
        conn_inflight_bytes,
    };
    let server = Server::bind_with(svc.handle(), &addr, net)?;
    println!(
        "serving on {} (workers {workers}, queue depth {queue_depth}, batch max {batch_max}, \
         policy {}, eb_rel {eb:.0e}, {chunk_elems} elems/chunk)",
        server.local_addr(),
        policy.name()
    );
    match &archive_dir {
        Some(dir) => println!(
            "archive at {} (mem budget {} B, {} open readers): recovered {} fields \
             from {} shards ({} corrupt skipped)",
            dir.display(),
            archive_mem,
            archive_readers,
            recovered.recovered_fields,
            recovered.recovered_shards,
            recovered.corrupt_shards,
        ),
        None => println!("archive in memory only (no --archive-dir: nothing survives restart)"),
    }
    server.run()?;
    // Shutdown requested by a client: drain, join, flush, report.
    println!("{}", svc.shutdown().summary());
    Ok(())
}

fn cmd_client(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7845").to_string();
    let op = args.get("op").unwrap_or("stats").to_string();
    // Transport deadlines (0 = disabled) and the reconnect-and-retry
    // budget for expiries — retrying is safe, every op is idempotent.
    let timeout_ms: u64 = args.get_or("timeout-ms", 30_000)?;
    let timeout_retries: u32 = args.get_or("timeout-retries", 2)?;
    let net_cfg = ClientConfig {
        read_timeout: std::time::Duration::from_millis(timeout_ms),
        write_timeout: std::time::Duration::from_millis(timeout_ms),
        timeout_retries,
        ..ClientConfig::default()
    };
    match op.as_str() {
        "compress" => {
            let fields = load_dataset(&args)?;
            let retry_ms: u64 = args.get_or("retry-ms", 10)?;
            let retries: u32 = args.get_or("retries", 500)?;
            // Frame pipelining: keep up to N compress requests in
            // flight on the one connection. Depth 1 is the serial
            // path with per-field Busy retries; deeper pipelines do
            // not retry (a Busy fails the run — raise --queue-depth
            // or lower --pipeline instead).
            let pipeline: usize = args.get_or("pipeline", 1)?;
            args.check_unknown()?;
            let mut client = Client::connect_with(&addr, net_cfg)?;
            let t0 = std::time::Instant::now();
            if pipeline > 1 {
                let acks = client.compress_pipelined(&fields, pipeline)?;
                let (mut raw, mut stored) = (0u64, 0u64);
                for ack in &acks {
                    raw += ack.raw_bytes;
                    stored += ack.stored_bytes;
                    println!(
                        "compressed {:<22} {:>10} -> {:>9} bytes ({} chunks, batch of {})",
                        ack.name, ack.raw_bytes, ack.stored_bytes, ack.chunks, ack.batch_size
                    );
                }
                println!(
                    "client: {} fields (pipeline depth {pipeline}), {} -> {} bytes \
                     (ratio {:.2}), wall {:.2}s",
                    fields.len(),
                    raw,
                    stored,
                    raw as f64 / stored.max(1) as f64,
                    t0.elapsed().as_secs_f64()
                );
                return Ok(());
            }
            let (mut raw, mut stored, mut busy) = (0u64, 0u64, 0u64);
            for f in &fields {
                // Busy is the admission-control signal, not a failure:
                // back off and retry (bounded), counting what we absorbed.
                let mut attempt = 0u32;
                let ack = loop {
                    match client.compress(f) {
                        Ok(ack) => break ack,
                        Err(Error::Busy) if attempt < retries => {
                            busy += 1;
                            attempt += 1;
                            std::thread::sleep(std::time::Duration::from_millis(retry_ms));
                        }
                        Err(e) => return Err(e),
                    }
                };
                raw += ack.raw_bytes;
                stored += ack.stored_bytes;
                println!(
                    "compressed {:<22} {:>10} -> {:>9} bytes ({} chunks, batch of {})",
                    ack.name, ack.raw_bytes, ack.stored_bytes, ack.chunks, ack.batch_size
                );
            }
            println!(
                "client: {} fields, {} -> {} bytes (ratio {:.2}), {busy} busy retries, \
                 wall {:.2}s",
                fields.len(),
                raw,
                stored,
                raw as f64 / stored.max(1) as f64,
                t0.elapsed().as_secs_f64()
            );
        }
        "fetch" => {
            let name = args.require("field")?.to_string();
            let out = args.get("out").map(str::to_string);
            args.check_unknown()?;
            let field = Client::connect_with(&addr, net_cfg)?.fetch(&name)?;
            match out {
                Some(path) => {
                    use std::io::Write as _;
                    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
                    for v in &field.data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                    w.flush()?;
                    println!(
                        "fetched {} ({} values, dims {}) -> {path}",
                        field.name,
                        field.data.len(),
                        field.dims
                    );
                }
                None => println!(
                    "fetched {} ({} values, dims {})",
                    field.name,
                    field.data.len(),
                    field.dims
                ),
            }
        }
        "stats" => {
            args.check_unknown()?;
            println!("{}", Client::connect_with(&addr, net_cfg)?.stats()?);
        }
        "shutdown" => {
            args.check_unknown()?;
            Client::connect_with(&addr, net_cfg)?.shutdown()?;
            println!("server shutdown requested");
        }
        other => {
            return Err(Error::InvalidArg(format!(
                "unknown --op '{other}' (expected compress, fetch, stats, shutdown)"
            )))
        }
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let input = args.require("in")?.to_string();
    args.check_unknown()?;
    let r = ContainerReader::open(&input)?;
    let registry = AutoSelector::default().registry();
    println!(
        "{input}: container v{}, {} fields, {} raw -> {} stored (ratio {:.2}); \
         answered from {} index bytes, payload untouched",
        r.version,
        r.fields.len(),
        r.raw_bytes(),
        r.stored_bytes(),
        r.raw_bytes() as f64 / r.stored_bytes() as f64,
        r.index_bytes()
    );
    for f in &r.fields {
        // Single-chunk fields show their codec; chunked fields the count.
        let codec = if f.chunks.len() == 1 {
            registry.name_of(f.chunks[0].selection).to_string()
        } else {
            format!("{}ch", f.chunks.len())
        };
        let dims = f.dims.map(|d| d.to_string()).unwrap_or_else(|| "?".into());
        println!(
            "  {:<22} {:>6} {:>12} {:>12} -> {:>10} bytes (x{:.2})",
            f.name,
            codec,
            dims,
            f.raw_bytes,
            f.stored_bytes(),
            f.raw_bytes as f64 / f.stored_bytes() as f64
        );
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let input = args.require("in")?.to_string();
    args.check_unknown()?;
    let r = ContainerReader::open(&input)?;
    let registry = AutoSelector::default().registry();
    println!(
        "{input}: container v{}, {} fields (index-only open: {} of {} bytes read)",
        r.version,
        r.fields.len(),
        r.index_bytes(),
        r.source_len()
    );
    // Per-codec byte totals across the whole container.
    let mut totals: std::collections::BTreeMap<u8, (usize, u64)> = Default::default();
    for f in &r.fields {
        // Selection map: one letter per chunk (first letter of the
        // codec name; '?' for unregistered ids).
        let map: String = f
            .chunks
            .iter()
            .map(|c| registry.name_of(c.selection).chars().next().unwrap_or('?'))
            .collect();
        for c in &f.chunks {
            let t = totals.entry(c.selection).or_insert((0, 0));
            t.0 += 1;
            t.1 += c.len as u64;
        }
        let chunk_note = if f.chunk_elems > 0 {
            format!(" ({} elems/chunk)", f.chunk_elems)
        } else {
            String::new()
        };
        println!("  {:<22} [{map}]{chunk_note}", f.name);
    }
    println!("per-codec totals:");
    for (sel, (chunks, bytes)) in &totals {
        println!(
            "  {:<6} (id {sel}): {chunks:>5} chunks, {bytes:>12} bytes",
            registry.name_of(*sel)
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_is_error() {
        assert!(run("frobnicate", &[]).is_err());
    }

    #[test]
    fn help_runs() {
        run("help", &[]).unwrap();
    }

    #[test]
    fn compress_then_info_and_decompress() {
        let tmp = std::env::temp_dir().join("adaptivec_cli_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("nyx.adaptivec");
        let argv: Vec<String> = [
            "--dataset", "nyx", "--scale", "0", "--eb", "1e-3", "--out",
            out.to_str().unwrap(), "--workers", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        run("info", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        let outdir = tmp.join("restored");
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir.to_str().unwrap().to_string(),
            ],
        )
        .unwrap();
        assert!(outdir.join("baryon_density.f32").is_file());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn chunked_compress_inspect_and_partial_decompress() {
        let tmp = std::env::temp_dir().join("adaptivec_cli_v2_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("atm.adaptivec2");
        let argv: Vec<String> = [
            "--dataset", "atm", "--scale", "0", "--eb", "1e-3", "--out",
            out.to_str().unwrap(), "--workers", "2", "--chunk-elems", "2048",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        run("info", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        run("inspect", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        // Partial decode of a single field out of the v2 container.
        let outdir = tmp.join("restored");
        let name = {
            let reader = ContainerReader::open(&out).unwrap();
            reader.fields[1].name.clone()
        };
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir.to_str().unwrap().to_string(),
                "--field".to_string(),
                name.clone(),
            ],
        )
        .unwrap();
        assert!(outdir.join(format!("{name}.f32")).is_file());
        // Full decompress walks the container field by field through
        // the pread-backed reader.
        let outdir_all = tmp.join("restored_all");
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir_all.to_str().unwrap().to_string(),
            ],
        )
        .unwrap();
        assert!(outdir_all.join(format!("{name}.f32")).is_file());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn dct_codecs_flag_emits_selection_byte_3_chunks() {
        use crate::codec_api::Choice;
        let tmp = std::env::temp_dir().join("adaptivec_cli_dct_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("hurricane.adaptivec2");
        let argv: Vec<String> = [
            "--dataset", "hurricane", "--scale", "0", "--eb", "1e-3", "--out",
            out.to_str().unwrap(), "--workers", "2", "--chunk-elems", "2048",
            "--codecs", "dct",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        // Every chunk of the chunked container is DCT-selected (byte 3).
        let reader = ContainerReader::open(&out).unwrap();
        assert_eq!(reader.version, 3);
        assert!(reader
            .fields
            .iter()
            .flat_map(|f| f.chunks.iter())
            .all(|c| c.selection == Choice::Dct.id()));
        // `inspect` resolves the chunks by registry name, no panic.
        run("inspect", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        // Partial decode of one DCT field round-trips.
        let name = reader.fields[0].name.clone();
        let outdir = tmp.join("restored");
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir.to_str().unwrap().to_string(),
                "--field".to_string(),
                name.clone(),
            ],
        )
        .unwrap();
        assert!(outdir.join(format!("{name}.f32")).is_file());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn pipelines_flag_selects_composed_pipeline_chunks() {
        use crate::codec_api::PIPE_BITROUND_SZ;
        let tmp = std::env::temp_dir().join("adaptivec_cli_pipelines_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("atm.adaptivec2");
        let argv: Vec<String> = [
            "--dataset", "atm", "--scale", "0", "--eb", "1e-3", "--out",
            out.to_str().unwrap(), "--workers", "2", "--chunk-elems", "2048",
            "--pipelines", "bitround+sz",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        // A pipeline-only candidate set selects the composed pipeline
        // (selection byte 4) for every chunk.
        let reader = ContainerReader::open(&out).unwrap();
        assert!(reader
            .fields
            .iter()
            .flat_map(|f| f.chunks.iter())
            .all(|c| c.selection == PIPE_BITROUND_SZ));
        // `inspect` resolves the composed chunks by registry name.
        run("inspect", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        // And the container decompresses back to per-field f32 files.
        let outdir = tmp.join("restored");
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir.to_str().unwrap().to_string(),
            ],
        )
        .unwrap();
        let name = reader.fields[0].name.clone();
        assert!(outdir.join(format!("{name}.f32")).is_file());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn codecs_and_pipelines_flags_are_exclusive() {
        let argv: Vec<String> = [
            "--dataset", "atm", "--scale", "0", "--codecs", "sz", "--pipelines",
            "delta+arith",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let err = run("select", &argv).unwrap_err();
        assert!(err.to_string().contains("not both"), "{err}");
        // Pipeline names are accepted through --codecs too (one shared
        // grammar), so mixed lists need only one flag.
        let argv: Vec<String> =
            ["--dataset", "atm", "--scale", "0", "--codecs", "sz,delta+arith"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run("select", &argv).unwrap();
    }

    #[test]
    fn write_plan_flag_both_protocols_roundtrip() {
        let tmp = std::env::temp_dir().join("adaptivec_cli_write_plan_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let single = tmp.join("single.adaptivec2");
        let two = tmp.join("two.adaptivec2");
        for (plan, out) in [("single", &single), ("two-pass", &two)] {
            let argv: Vec<String> = [
                "--dataset", "atm", "--scale", "0", "--eb", "1e-3", "--out",
                out.to_str().unwrap(), "--workers", "2", "--chunk-elems", "2048",
                "--write-plan", plan,
            ]
            .iter()
            .map(|s| s.to_string())
            .collect();
            run("compress", &argv).unwrap();
        }
        // The protocol is invisible in the bytes.
        assert_eq!(
            std::fs::read(&single).unwrap(),
            std::fs::read(&two).unwrap(),
            "write plans must produce identical containers"
        );
        // --spill-mem 0 forces the temp-file path; output unchanged.
        let spilled = tmp.join("spilled.adaptivec2");
        let argv: Vec<String> = [
            "--dataset", "atm", "--scale", "0", "--eb", "1e-3", "--out",
            spilled.to_str().unwrap(), "--workers", "2", "--chunk-elems", "2048",
            "--spill-mem", "0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        assert_eq!(std::fs::read(&single).unwrap(), std::fs::read(&spilled).unwrap());
        // Unknown plan names are rejected.
        let argv: Vec<String> =
            ["--dataset", "atm", "--scale", "0", "--write-plan", "zigzag"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert!(run("compress", &argv).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn serve_client_loopback_roundtrip() {
        // Let the OS pick a free port (bind :0, read it back, release
        // it) so parallel test runs cannot collide on a fixed number.
        let addr = {
            let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            probe.local_addr().unwrap().to_string()
        };
        fn argv(parts: &[&str]) -> Vec<String> {
            parts.iter().map(|s| s.to_string()).collect()
        }
        let server = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run(
                    "serve",
                    &argv(&[
                        "--addr", &addr, "--workers", "1", "--eb", "1e-3",
                        "--chunk-elems", "2048", "--queue-depth", "8",
                    ]),
                )
            })
        };
        // Wait for the listener to come up.
        let mut up = false;
        for _ in 0..100 {
            if std::net::TcpStream::connect(&addr).is_ok() {
                up = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        assert!(up, "server never bound {addr}");

        run(
            "client",
            &argv(&["--addr", &addr, "--op", "compress", "--dataset", "nyx", "--scale", "0"]),
        )
        .unwrap();
        let tmp = std::env::temp_dir().join("adaptivec_cli_serve_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("svc_field.f32");
        run(
            "client",
            &argv(&[
                "--addr", &addr, "--op", "fetch", "--field", "baryon_density",
                "--out", out.to_str().unwrap(),
            ]),
        )
        .unwrap();
        assert!(out.is_file());
        assert!(std::fs::metadata(&out).unwrap().len() > 0);
        run("client", &argv(&["--addr", &addr, "--op", "stats"])).unwrap();
        run("client", &argv(&["--addr", &addr, "--op", "shutdown"])).unwrap();
        server.join().unwrap().unwrap();
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn bad_codecs_flag_rejected() {
        let argv: Vec<String> = ["--dataset", "atm", "--codecs", "zstd"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run("estimate", &argv).is_err());
    }
}
