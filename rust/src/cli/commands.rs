//! The `adaptivec` subcommands:
//!
//! * `compress`   — compress a dataset (or a raw f32 file) with a policy
//! * `decompress` — restore a container to raw f32 files
//! * `estimate`   — print Algorithm 1's estimates for every field
//! * `select`     — selection decisions only (Fig. 6-style map)
//! * `sweep`      — compression-ratio sweep over error bounds (Fig. 7)
//! * `iobench`    — modeled parallel store/load throughput (Figs. 8–9)
//! * `info`       — inspect a container

use super::args::Args;
use crate::baseline::Policy;
use crate::coordinator::{store::Container, Coordinator};
use crate::data::{Dataset, Field};
use crate::estimator::selector::{AutoSelector, SelectorConfig};
use crate::iosim::{FsModel, ThroughputModel, PROC_SWEEP};
use crate::{Error, Result};

pub const USAGE: &str = "adaptivec — online rate-distortion-optimal SZ/ZFP selection

USAGE:
  adaptivec <command> [options]

COMMANDS:
  compress    --dataset <nyx|atm|hurricane> [--scale 0|1|2] [--eb 1e-4]
              [--policy ours|sz|zfp|eb|optimum|baseline] [--workers N]
              [--out FILE] [--seed N]
  decompress  --in FILE [--outdir DIR]
  estimate    --dataset D [--scale S] [--eb E] [--rsp 0.05]
  select      --dataset D [--scale S] [--eb E]
  sweep       --dataset D [--scale S] [--bounds 1e-3,1e-4,1e-6]
  iobench     --dataset D [--scale S] [--eb E]
  info        --in FILE
";

fn selector_cfg(args: &Args) -> Result<SelectorConfig> {
    let mut cfg = SelectorConfig::default();
    cfg.r_sp = args.get_or("rsp", cfg.r_sp)?;
    Ok(cfg)
}

fn load_dataset(args: &Args) -> Result<Vec<Field>> {
    let name = args.require("dataset")?.to_string();
    let ds = Dataset::parse(&name)
        .ok_or_else(|| Error::InvalidArg(format!("unknown dataset '{name}'")))?;
    let scale: u8 = args.get_or("scale", 1)?;
    let seed: u64 = args.get_or("seed", 2018)?;
    Ok(ds.generate(seed, scale))
}

/// Entry point: dispatch a subcommand.
pub fn run(cmd: &str, argv: &[String]) -> Result<()> {
    match cmd {
        "compress" => cmd_compress(argv),
        "decompress" => cmd_decompress(argv),
        "estimate" => cmd_estimate(argv),
        "select" => cmd_select(argv),
        "sweep" => cmd_sweep(argv),
        "iobench" => cmd_iobench(argv),
        "info" => cmd_info(argv),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::InvalidArg(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn cmd_compress(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let policy = Policy::parse(args.get("policy").unwrap_or("ours"))
        .ok_or_else(|| Error::InvalidArg("bad --policy".into()))?;
    let workers: usize = args.get_or("workers", 0)?;
    let out = args.get("out").unwrap_or("out.adaptivec").to_string();
    args.check_unknown()?;

    let coord = Coordinator::new(
        selector_cfg(&Args::parse(&[], &[])?)?,
        if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            workers
        },
    );
    let t0 = std::time::Instant::now();
    let report = coord.run(&fields, policy, eb)?;
    let wall = t0.elapsed();
    report.to_container().write_file(&out)?;
    let (sz, zfp) = report.choice_counts();
    println!(
        "{} fields, policy {}, eb_rel {eb:.0e}: ratio {:.2} ({} -> {} bytes), \
         SZ {sz} / ZFP {zfp}, est-overhead {:.1}%, wall {:.2}s -> {out}",
        report.results.len(),
        policy.name(),
        report.overall_ratio(),
        report.total_raw_bytes(),
        report.total_stored_bytes(),
        report.overhead_frac() * 100.0,
        wall.as_secs_f64(),
    );
    Ok(())
}

fn cmd_decompress(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let input = args.require("in")?.to_string();
    let outdir = args.get("outdir").unwrap_or(".").to_string();
    args.check_unknown()?;
    let container = Container::read_file(&input)?;
    let coord = Coordinator::default();
    let fields = coord.load(&container)?;
    std::fs::create_dir_all(&outdir)?;
    for f in &fields {
        let path = format!("{outdir}/{}.f32", f.name);
        let mut bytes = Vec::with_capacity(f.raw_bytes());
        for v in &f.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes)?;
    }
    println!("restored {} fields to {outdir}/", fields.len());
    Ok(())
}

fn cmd_estimate(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;
    let sel = AutoSelector::new(cfg);
    println!(
        "{:<22} {:>9} {:>9} {:>10} {:>6}",
        "field", "BR_sz", "BR_zfp", "PSNR_tgt", "pick"
    );
    for f in &fields {
        let (choice, est) = sel.select(f, eb)?;
        println!(
            "{:<22} {:>9.3} {:>9.3} {:>10.2} {:>6}",
            f.name,
            est.br_sz,
            est.br_zfp,
            est.psnr_target,
            choice.name()
        );
    }
    Ok(())
}

fn cmd_select(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    let cfg = selector_cfg(&args)?;
    args.check_unknown()?;
    let sel = AutoSelector::new(cfg);
    let mut counts = (0usize, 0usize);
    for f in &fields {
        let (choice, _) = sel.select(f, eb)?;
        match choice {
            crate::estimator::Choice::Sz => counts.0 += 1,
            crate::estimator::Choice::Zfp => counts.1 += 1,
        }
        println!("{:<22} -> {}", f.name, choice.name());
    }
    println!(
        "summary: SZ {} ({:.1}%), ZFP {}",
        counts.0,
        100.0 * counts.0 as f64 / fields.len() as f64,
        counts.1
    );
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let bounds: Vec<f64> = args
        .get("bounds")
        .unwrap_or("1e-3,1e-4,1e-6")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|_| Error::InvalidArg(format!("bad bound {s}"))))
        .collect::<Result<_>>()?;
    args.check_unknown()?;
    let coord = Coordinator::default();
    println!("{:>8} {:>10} {:>10} {:>10} {:>10}", "eb_rel", "SZ", "ZFP", "ours", "optimum");
    for &eb in &bounds {
        let mut row = Vec::new();
        for p in [Policy::AlwaysSz, Policy::AlwaysZfp, Policy::RateDistortion, Policy::Optimum] {
            let report = coord.run(&fields, p, eb)?;
            row.push(report.overall_ratio());
        }
        println!(
            "{eb:>8.0e} {:>10.2} {:>10.2} {:>10.2} {:>10.2}",
            row[0], row[1], row[2], row[3]
        );
    }
    Ok(())
}

fn cmd_iobench(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let fields = load_dataset(&args)?;
    let eb: f64 = args.get_or("eb", 1e-4)?;
    args.check_unknown()?;
    let coord = Coordinator::default();
    let tm = ThroughputModel::new(FsModel::default());

    println!("store/load throughput model (GB/s of raw data), eb_rel {eb:.0e}");
    println!("{:>6} {:>10} {:>10} {:>10} {:>10}", "procs", "baseline", "SZ", "ZFP", "ours");
    let mut per_policy = Vec::new();
    for p in [Policy::NoCompression, Policy::AlwaysSz, Policy::AlwaysZfp, Policy::RateDistortion]
    {
        let report = coord.run(&fields, p, eb)?;
        let raw = report.total_raw_bytes() as f64;
        let stored = report.total_stored_bytes() as f64;
        let comp_t = report.total_compress_time().as_secs_f64()
            + report.total_estimate_time().as_secs_f64();
        per_policy.push((raw, stored, comp_t));
    }
    for &p in &PROC_SWEEP {
        print!("{p:>6}");
        for &(raw, stored, comp_t) in &per_policy {
            let tput = tm.store_throughput(p, raw, stored, comp_t);
            print!(" {:>10.2}", tput / 1e9);
        }
        println!();
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &[])?;
    let input = args.require("in")?.to_string();
    args.check_unknown()?;
    let c = Container::read_file(&input)?;
    println!(
        "{input}: {} fields, {} raw -> {} stored (ratio {:.2})",
        c.entries.len(),
        c.raw_bytes(),
        c.stored_bytes(),
        c.raw_bytes() as f64 / c.stored_bytes() as f64
    );
    for e in &c.entries {
        let codec = match e.selection {
            0 => "SZ",
            1 => "ZFP",
            _ => "raw",
        };
        println!(
            "  {:<22} {:>5} {:>12} -> {:>10} bytes (x{:.2})",
            e.name,
            codec,
            e.raw_bytes,
            e.payload.len(),
            e.raw_bytes as f64 / e.payload.len() as f64
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_is_error() {
        assert!(run("frobnicate", &[]).is_err());
    }

    #[test]
    fn help_runs() {
        run("help", &[]).unwrap();
    }

    #[test]
    fn compress_then_info_and_decompress() {
        let tmp = std::env::temp_dir().join("adaptivec_cli_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let out = tmp.join("nyx.adaptivec");
        let argv: Vec<String> = [
            "--dataset", "nyx", "--scale", "0", "--eb", "1e-3", "--out",
            out.to_str().unwrap(), "--workers", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run("compress", &argv).unwrap();
        run("info", &["--in".to_string(), out.to_str().unwrap().to_string()]).unwrap();
        let outdir = tmp.join("restored");
        run(
            "decompress",
            &[
                "--in".to_string(),
                out.to_str().unwrap().to_string(),
                "--outdir".to_string(),
                outdir.to_str().unwrap().to_string(),
            ],
        )
        .unwrap();
        assert!(outdir.join("baryon_density.f32").is_file());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
