//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §9):
//! `--key value` / `--flag` parsing plus the `adaptivec` subcommands.

pub mod args;
pub mod commands;

pub use args::Args;
