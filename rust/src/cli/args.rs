//! `--key value` argument parsing with typed accessors and unknown-
//! option detection.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse raw argv (after the subcommand). Options take a value
    /// unless listed in `flag_names`.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if flag_names.contains(&name) {
                    a.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| Error::InvalidArg(format!("--{name} needs a value")))?;
                    if a.options.insert(name.to_string(), val.clone()).is_some() {
                        return Err(Error::InvalidArg(format!("duplicate option --{name}")));
                    }
                }
            } else {
                a.positionals.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArg(format!("--{name}: cannot parse '{v}'"))),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::InvalidArg(format!("missing required option --{name}")))
    }

    /// Error on any option the command never consumed (catches typos).
    pub fn check_unknown(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.options.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(Error::InvalidArg(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn options_flags_positionals() {
        let a = Args::parse(&argv(&["in.bin", "--eb", "1e-4", "--verbose"]), &["verbose"])
            .unwrap();
        assert_eq!(a.positionals, vec!["in.bin"]);
        assert_eq!(a.get("eb"), Some("1e-4"));
        assert!(a.flag("verbose"));
        a.check_unknown().unwrap();
    }

    #[test]
    fn typed_and_defaults() {
        let a = Args::parse(&argv(&["--workers", "8"]), &[]).unwrap();
        assert_eq!(a.get_or("workers", 1usize).unwrap(), 8);
        assert_eq!(a.get_or("scale", 1u8).unwrap(), 1);
    }

    #[test]
    fn missing_value_and_duplicates() {
        assert!(Args::parse(&argv(&["--eb"]), &[]).is_err());
        assert!(Args::parse(&argv(&["--a", "1", "--a", "2"]), &[]).is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = Args::parse(&argv(&["--typo", "1"]), &[]).unwrap();
        let _ = a.get("other");
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn require_errors_when_absent() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert!(a.require("input").is_err());
    }
}
