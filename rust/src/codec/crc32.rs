//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the
//! per-chunk payload checksum of the `ADAPTC03` container index
//! (DESIGN.md §6). Hand-rolled and std-only: the offline build has no
//! `crc32fast` (DESIGN.md §9), and the container only needs bit-rot
//! detection, not cryptographic strength. CRC-32 detects all single-bit
//! and all burst errors up to 32 bits, which is exactly the "flipped
//! bits surface at read time, not as a confusing codec `Corrupt`"
//! contract the store wants.
//!
//! Three implementations compute the same digests (DESIGN.md §13):
//!
//! * **hardware** — PCLMULQDQ carry-less-multiply folding (Gopal et
//!   al., "Fast CRC Computation for Generic Polynomials Using
//!   PCLMULQDQ", Intel 2009): 64 input bytes per fold iteration across
//!   four independent 128-bit lanes, then a Barrett reduction back to
//!   32 bits. The SSE4.2 `crc32` *instruction* is hardwired to the
//!   Castagnoli polynomial and cannot produce IEEE digests, so the
//!   clmul route is the only way to go hardware-speed without
//!   breaking every checksum already on disk. x86-64 only, selected
//!   at runtime via `is_x86_feature_detected!`.
//! * **slice-by-8** — eight compile-time tables fold eight input
//!   bytes per iteration with eight independent lookups; the portable
//!   fast path and the fallback when clmul is unavailable.
//! * **bytewise** — the classic one-byte-per-step table walk
//!   ([`update_bytewise`]): the reference the other two are
//!   differentially tested against, and the tail handler for short
//!   remainders.
//!
//! [`update`] dispatches between them through a once-per-process
//! backend choice; `ADAPTIVEC_FORCE_CRC=bytewise|slice8|hw` pins the
//! backend so CI can run the full suite on every implementation.

/// Slice-by-8 lookup tables for the reflected IEEE polynomial,
/// generated at compile time. `TABLES[0]` is the classic byte table;
/// `TABLES[k][i]` is the CRC of byte `i` followed by `k` zero bytes,
/// so eight lookups advance the state by eight input bytes at once.
const TABLES: [[u32; 256]; 8] = {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
};

/// Which implementation [`update`] routes through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// One-byte-per-step table walk (the reference).
    Bytewise,
    /// Slice-by-8 table folding (portable fast path).
    Slice8,
    /// PCLMULQDQ carry-less-multiply folding (x86-64 with clmul).
    Hw,
}

impl Backend {
    /// Parse an `ADAPTIVEC_FORCE_CRC` value.
    fn from_name(name: &str) -> Option<Backend> {
        match name {
            "bytewise" => Some(Backend::Bytewise),
            "slice8" => Some(Backend::Slice8),
            "hw" => Some(Backend::Hw),
            _ => None,
        }
    }

    /// Short name (bench/report labels).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Bytewise => "bytewise",
            Backend::Slice8 => "slice8",
            Backend::Hw => "hw",
        }
    }
}

/// Whether the clmul hardware path can run on this CPU.
pub fn hw_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("pclmulqdq")
            && std::arch::is_x86_feature_detected!("sse4.1")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The backend [`update`] uses, chosen once per process: the
/// `ADAPTIVEC_FORCE_CRC` override if set (a forced `hw` on a machine
/// without clmul falls back to slice-by-8 rather than erroring —
/// digests are identical either way), otherwise hardware when
/// available, slice-by-8 when not.
pub fn active_backend() -> Backend {
    static CHOICE: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();
    *CHOICE.get_or_init(|| {
        let forced = std::env::var("ADAPTIVEC_FORCE_CRC")
            .ok()
            .and_then(|v| Backend::from_name(v.trim().to_lowercase().as_str()));
        match forced {
            Some(Backend::Hw) | None => {
                if hw_available() {
                    Backend::Hw
                } else {
                    Backend::Slice8
                }
            }
            Some(b) => b,
        }
    })
}

/// CRC-32 of `bytes` (initial value 0, i.e. a fresh stream).
#[inline]
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0, bytes)
}

/// Continue a CRC-32 over more bytes: `update(update(0, a), b) ==
/// crc32(a ++ b)`, so streamed producers can checksum incrementally.
/// Routes through the [`active_backend`]; digests are byte-identical
/// across all three implementations (differentially tested).
#[inline]
pub fn update(crc: u32, bytes: &[u8]) -> u32 {
    match active_backend() {
        Backend::Bytewise => update_bytewise(crc, bytes),
        Backend::Slice8 => update_slice8(crc, bytes),
        Backend::Hw => update_hw(crc, bytes).unwrap_or_else(|| update_slice8(crc, bytes)),
    }
}

/// Hardware (clmul) update; `None` when this CPU cannot run it.
/// Public so the differential tests and the `hotpath` bench can pin
/// this exact implementation regardless of the active backend.
pub fn update_hw(crc: u32, bytes: &[u8]) -> Option<u32> {
    #[cfg(target_arch = "x86_64")]
    {
        if hw_available() {
            // SAFETY: pclmulqdq + sse4.1 were just verified present.
            return Some(unsafe { hw::update(crc, bytes) });
        }
    }
    let _ = (crc, bytes);
    None
}

/// Slice-by-8 update: eight bytes per iteration over the aligned
/// body, byte-at-a-time over the tail.
pub fn update_slice8(crc: u32, bytes: &[u8]) -> u32 {
    let mut state = !crc;
    let mut chunks = bytes.chunks_exact(8);
    for c in chunks.by_ref() {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ state;
        state = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][c[4] as usize]
            ^ TABLES[2][c[5] as usize]
            ^ TABLES[1][c[6] as usize]
            ^ TABLES[0][c[7] as usize];
    }
    for &b in chunks.remainder() {
        state = TABLES[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

/// The original table-driven byte-at-a-time update — the reference
/// implementation the slice-by-8 path is verified against (and the
/// code path short tails take). Same digests, one byte per step.
pub fn update_bytewise(crc: u32, bytes: &[u8]) -> u32 {
    let mut state = !crc;
    for &b in bytes {
        state = TABLES[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

/// PCLMULQDQ folding for the reflected IEEE polynomial. The constants
/// are `x^n mod P(x)` for the fold distances the loop uses (bit-
/// reflected, as published in the Intel whitepaper and used by zlib
/// and the Linux kernel); the structure is: fold 64 bytes/iteration
/// across four lanes, merge the lanes, fold the 16-byte stragglers,
/// reduce 128→64→32 bits, and finish with a Barrett reduction. The
/// whole pipeline was verified lane-for-lane against a software model
/// of the intrinsics, and the unit tests assert digest identity with
/// [`update_bytewise`] at every length 0..=256.
#[cfg(target_arch = "x86_64")]
mod hw {
    #[allow(clippy::wildcard_imports)]
    use core::arch::x86_64::*;

    /// x^(4·128+32) mod P — lane fold low.
    const K1: i64 = 0x0154_442b_d4;
    /// x^(4·128−32) mod P — lane fold high.
    const K2: i64 = 0x01c6_e415_96;
    /// x^(128+32) mod P — merge fold low.
    const K3: i64 = 0x0175_1997_d0;
    /// x^(128−32) mod P — merge fold high.
    const K4: i64 = 0x00cc_aa00_9e;
    /// x^64 mod P — 96→64 reduction.
    const K5: i64 = 0x0163_cd61_24;
    /// P(x) bit-reflected, with the implicit leading bit.
    const POLY: i64 = 0x01db_7106_41;
    /// Barrett constant μ = ⌊x^64 / P(x)⌋, bit-reflected.
    const MU: i64 = 0x01f7_0116_41;

    /// Unaligned 16-byte load from the head of `p`.
    #[inline]
    unsafe fn load(p: &[u8]) -> __m128i {
        debug_assert!(p.len() >= 16);
        _mm_loadu_si128(p.as_ptr() as *const __m128i)
    }

    /// One 128-bit fold step: `a` advanced 128 bits and xor-folded
    /// into `b` (k holds the two fold constants in its lanes).
    #[inline]
    unsafe fn fold16(a: __m128i, b: __m128i, k: __m128i) -> __m128i {
        let lo = _mm_clmulepi64_si128(a, k, 0x00);
        let hi = _mm_clmulepi64_si128(a, k, 0x11);
        _mm_xor_si128(_mm_xor_si128(b, lo), hi)
    }

    /// Same API semantics as [`super::update_slice8`] — callers pass
    /// the public (post-complement) crc and get one back.
    #[target_feature(enable = "pclmulqdq", enable = "sse2", enable = "sse4.1")]
    pub unsafe fn update(crc: u32, bytes: &[u8]) -> u32 {
        // The fold loop needs four full lanes; short inputs take the
        // table path (identical digests).
        if bytes.len() < 64 {
            return super::update_slice8(crc, bytes);
        }
        let mut chunks = bytes.chunks_exact(64);
        let first = chunks.next().expect("len checked >= 64");
        let mut x3 = load(first);
        let mut x2 = load(&first[16..]);
        let mut x1 = load(&first[32..]);
        let mut x0 = load(&first[48..]);
        // Fold the incoming state into the first lane (the stream
        // convention keeps the complemented state, like the tables).
        x3 = _mm_xor_si128(x3, _mm_cvtsi32_si128(!crc as i32));

        let k1k2 = _mm_set_epi64x(K2, K1);
        for c in chunks.by_ref() {
            x3 = fold16(x3, load(c), k1k2);
            x2 = fold16(x2, load(&c[16..]), k1k2);
            x1 = fold16(x1, load(&c[32..]), k1k2);
            x0 = fold16(x0, load(&c[48..]), k1k2);
        }

        // Merge the four lanes into one 128-bit accumulator.
        let k3k4 = _mm_set_epi64x(K4, K3);
        let mut x = fold16(x3, x2, k3k4);
        x = fold16(x, x1, k3k4);
        x = fold16(x, x0, k3k4);

        // Fold whole 16-byte blocks the 64-byte loop left behind.
        let mut rest = chunks.remainder();
        while rest.len() >= 16 {
            x = fold16(x, load(rest), k3k4);
            rest = &rest[16..];
        }

        // Reduce 128 → 64 bits, then 96 → 64 via K5.
        let mask32 = _mm_set_epi32(0, 0, 0, !0);
        let x = _mm_xor_si128(_mm_clmulepi64_si128(x, k3k4, 0x10), _mm_srli_si128(x, 8));
        let x = _mm_xor_si128(
            _mm_clmulepi64_si128(_mm_and_si128(x, mask32), _mm_set_epi64x(0, K5), 0x00),
            _mm_srli_si128(x, 4),
        );

        // Barrett reduction 64 → 32 bits.
        let pu = _mm_set_epi64x(MU, POLY);
        let t1 = _mm_clmulepi64_si128(_mm_and_si128(x, mask32), pu, 0x10);
        let t2 = _mm_clmulepi64_si128(_mm_and_si128(t1, mask32), pu, 0x00);
        let state = _mm_extract_epi32(_mm_xor_si128(x, t2), 1) as u32;

        let api = !state;
        if rest.is_empty() {
            api
        } else {
            super::update_slice8(api, rest)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The standard CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        // 32 zero bytes are not a fixed point.
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn slice_by_8_matches_bytewise_at_every_length() {
        // Cross-check the fast path against the reference walk for
        // every length 0..=64 (covers empty, tail-only, exactly one
        // block, block + tail) and a long pseudo-random buffer.
        let data: Vec<u8> = (0u32..4096).map(|i| (i * 31 + (i >> 5) * 7) as u8).collect();
        for len in 0..=64usize {
            assert_eq!(
                update_slice8(0, &data[..len]),
                update_bytewise(0, &data[..len]),
                "len {len}"
            );
        }
        assert_eq!(update_slice8(0, &data), update_bytewise(0, &data));
        // And from a non-zero starting state.
        let mid = update_slice8(0, &data[..1000]);
        assert_eq!(
            update_slice8(mid, &data[1000..]),
            update_bytewise(mid, &data[1000..])
        );
    }

    #[test]
    fn hardware_matches_bytewise_at_every_length() {
        // Differential test for the clmul path: digest identity with
        // the reference walk at every length 0..=256 (covers the
        // short-input table fallback, exactly 64, 64 + 16k, and every
        // tail shape), from zero and non-zero starting states. On
        // machines without clmul `update_hw` returns `None` and the
        // fallback dispatch is what ships — nothing to test.
        if !hw_available() {
            return;
        }
        let data: Vec<u8> = (0u32..8192).map(|i| (i * 73 + (i >> 7) * 5) as u8).collect();
        for len in 0..=256usize {
            assert_eq!(
                update_hw(0, &data[..len]).unwrap(),
                update_bytewise(0, &data[..len]),
                "len {len}"
            );
        }
        assert_eq!(update_hw(0, &data).unwrap(), update_bytewise(0, &data));
        for split in [1usize, 63, 64, 65, 100, 4096] {
            let mid = update_bytewise(0, &data[..split]);
            assert_eq!(
                update_hw(mid, &data[split..]).unwrap(),
                update_bytewise(mid, &data[split..]),
                "split {split}"
            );
        }
        // Streaming through the hw path composes like the others.
        let mid = update_hw(0, &data[..977]).unwrap();
        assert_eq!(update_hw(mid, &data[977..]).unwrap(), crc32(&data));
    }

    #[test]
    fn backend_names_roundtrip() {
        for b in [Backend::Bytewise, Backend::Slice8, Backend::Hw] {
            assert_eq!(Backend::from_name(b.name()), Some(b));
        }
        assert_eq!(Backend::from_name("simd"), None);
        // The dispatching entry point agrees with the reference no
        // matter which backend the environment selected.
        let data: Vec<u8> = (0u16..300).map(|i| (i * 11) as u8).collect();
        assert_eq!(update(0, &data), update_bytewise(0, &data));
        let _ = active_backend();
    }

    #[test]
    fn incremental_update_matches_one_shot() {
        let data: Vec<u8> = (0u16..1500).map(|i| (i * 7 % 251) as u8).collect();
        for split in [0usize, 1, 2, 700, data.len() - 1, data.len()] {
            let inc = update(crc32(&data[..split]), &data[split..]);
            assert_eq!(inc, crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_always_detected() {
        // CRC-32 guarantees detection of every single-bit error; the
        // container fuzz tests lean on this, so pin it here.
        let data: Vec<u8> = (0u16..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for pos in (0..data.len()).step_by(17) {
            for bit in 0..8 {
                let mut c = data.clone();
                c[pos] ^= 1 << bit;
                assert_ne!(crc32(&c), base, "flip at {pos}.{bit} undetected");
            }
        }
    }
}
