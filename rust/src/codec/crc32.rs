//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the
//! per-chunk payload checksum of the `ADAPTC03` container index
//! (DESIGN.md §6). Hand-rolled and std-only: the offline build has no
//! `crc32fast` (DESIGN.md §9), and the container only needs bit-rot
//! detection, not cryptographic strength. Table-driven, one byte per
//! step; CRC-32 detects all single-bit and all burst errors up to 32
//! bits, which is exactly the "flipped bits surface at read time, not
//! as a confusing codec `Corrupt`" contract the store wants.

/// The 256-entry lookup table for the reflected IEEE polynomial,
/// generated at compile time.
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC-32 of `bytes` (initial value 0, i.e. a fresh stream).
#[inline]
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0, bytes)
}

/// Continue a CRC-32 over more bytes: `update(update(0, a), b) ==
/// crc32(a ++ b)`, so streamed producers can checksum incrementally.
pub fn update(crc: u32, bytes: &[u8]) -> u32 {
    let mut state = !crc;
    for &b in bytes {
        state = TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The standard CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        // 32 zero bytes are not a fixed point.
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn incremental_update_matches_one_shot() {
        let data: Vec<u8> = (0u16..1500).map(|i| (i * 7 % 251) as u8).collect();
        for split in [0usize, 1, 2, 700, data.len() - 1, data.len()] {
            let inc = update(crc32(&data[..split]), &data[split..]);
            assert_eq!(inc, crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_always_detected() {
        // CRC-32 guarantees detection of every single-bit error; the
        // container fuzz tests lean on this, so pin it here.
        let data: Vec<u8> = (0u16..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for pos in (0..data.len()).step_by(17) {
            for bit in 0..8 {
                let mut c = data.clone();
                c[pos] ^= 1 << bit;
                assert_ne!(crc32(&c), base, "flip at {pos}.{bit} undetected");
            }
        }
    }
}
