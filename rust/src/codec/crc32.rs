//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the
//! per-chunk payload checksum of the `ADAPTC03` container index
//! (DESIGN.md §6). Hand-rolled and std-only: the offline build has no
//! `crc32fast` (DESIGN.md §9), and the container only needs bit-rot
//! detection, not cryptographic strength. CRC-32 detects all single-bit
//! and all burst errors up to 32 bits, which is exactly the "flipped
//! bits surface at read time, not as a confusing codec `Corrupt`"
//! contract the store wants.
//!
//! The hot path is **slice-by-8**: eight compile-time tables let one
//! loop iteration fold eight input bytes into the state with eight
//! independent table lookups (no loop-carried dependency between
//! them), instead of the classic one-byte-per-step walk — the software
//! half of the ROADMAP "CRC hardware path" item, cutting checksum
//! overhead on multi-GB archives without touching the public API or
//! the digests. The byte-at-a-time path survives as
//! [`update_bytewise`], both as the tail handler for non-multiple-of-8
//! lengths and as the reference the unit tests cross-check against.

/// Slice-by-8 lookup tables for the reflected IEEE polynomial,
/// generated at compile time. `TABLES[0]` is the classic byte table;
/// `TABLES[k][i]` is the CRC of byte `i` followed by `k` zero bytes,
/// so eight lookups advance the state by eight input bytes at once.
const TABLES: [[u32; 256]; 8] = {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
};

/// CRC-32 of `bytes` (initial value 0, i.e. a fresh stream).
#[inline]
pub fn crc32(bytes: &[u8]) -> u32 {
    update(0, bytes)
}

/// Continue a CRC-32 over more bytes: `update(update(0, a), b) ==
/// crc32(a ++ b)`, so streamed producers can checksum incrementally.
/// Slice-by-8 over the 8-byte-aligned body, byte-at-a-time over the
/// tail — digests are byte-identical to [`update_bytewise`].
pub fn update(crc: u32, bytes: &[u8]) -> u32 {
    let mut state = !crc;
    let mut chunks = bytes.chunks_exact(8);
    for c in chunks.by_ref() {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ state;
        state = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][c[4] as usize]
            ^ TABLES[2][c[5] as usize]
            ^ TABLES[1][c[6] as usize]
            ^ TABLES[0][c[7] as usize];
    }
    for &b in chunks.remainder() {
        state = TABLES[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

/// The original table-driven byte-at-a-time update — the reference
/// implementation the slice-by-8 path is verified against (and the
/// code path short tails take). Same digests, one byte per step.
pub fn update_bytewise(crc: u32, bytes: &[u8]) -> u32 {
    let mut state = !crc;
    for &b in bytes {
        state = TABLES[0][((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The standard CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        // 32 zero bytes are not a fixed point.
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn slice_by_8_matches_bytewise_at_every_length() {
        // Cross-check the fast path against the reference walk for
        // every length 0..=64 (covers empty, tail-only, exactly one
        // block, block + tail) and a long pseudo-random buffer.
        let data: Vec<u8> = (0u32..4096).map(|i| (i * 31 + (i >> 5) * 7) as u8).collect();
        for len in 0..=64usize {
            assert_eq!(
                update(0, &data[..len]),
                update_bytewise(0, &data[..len]),
                "len {len}"
            );
        }
        assert_eq!(update(0, &data), update_bytewise(0, &data));
        // And from a non-zero starting state.
        let mid = update(0, &data[..1000]);
        assert_eq!(update(mid, &data[1000..]), update_bytewise(mid, &data[1000..]));
    }

    #[test]
    fn incremental_update_matches_one_shot() {
        let data: Vec<u8> = (0u16..1500).map(|i| (i * 7 % 251) as u8).collect();
        for split in [0usize, 1, 2, 700, data.len() - 1, data.len()] {
            let inc = update(crc32(&data[..split]), &data[split..]);
            assert_eq!(inc, crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_always_detected() {
        // CRC-32 guarantees detection of every single-bit error; the
        // container fuzz tests lean on this, so pin it here.
        let data: Vec<u8> = (0u16..256).map(|i| i as u8).collect();
        let base = crc32(&data);
        for pos in (0..data.len()).step_by(17) {
            for bit in 0..8 {
                let mut c = data.clone();
                c[pos] ^= 1 << bit;
                assert_ne!(crc32(&c), base, "flip at {pos}.{bit} undetected");
            }
        }
    }
}
