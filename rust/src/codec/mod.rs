//! Shared entropy-coding substrate: bit-level streams and canonical
//! Huffman coding. Used by both the [`crate::sz`] (Stage III entropy
//! coding) and [`crate::zfp`] (bit-plane embedded coding) compressors
//! and by the container format in [`crate::coordinator::store`].

pub mod arith;
pub mod bitstream;
pub mod crc32;
pub mod huffman;
pub mod varint;

pub use bitstream::{BitReader, BitWriter};
pub use huffman::{HuffmanDecoder, HuffmanEncoder};
