//! LEB128-style variable-length integers for headers and container
//! metadata.

use crate::{Error, Result};

/// Append `v` as LEB128.
pub fn write_u64(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a LEB128 u64 from `buf[*pos..]`, advancing `pos`.
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::Corrupt("varint: unexpected end of buffer".into()))?;
        *pos += 1;
        if shift >= 64 {
            return Err(Error::Corrupt("varint: overflow".into()));
        }
        let bits = (byte & 0x7F) as u64;
        // Payload bits past bit 63 would be shifted out silently,
        // letting distinct corrupt encodings decode to the same value —
        // reject anything that doesn't fit the remaining width (only
        // reachable on the 10th byte, where 1 payload bit remains).
        if shift > 57 && (bits >> (64 - shift)) != 0 {
            return Err(Error::Corrupt("varint: overflow".into()));
        }
        v |= bits << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Append a length-prefixed byte slice.
pub fn write_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    write_u64(out, bytes.len() as u64);
    out.extend_from_slice(bytes);
}

/// Read a length-prefixed byte slice.
pub fn read_bytes<'a>(buf: &'a [u8], pos: &mut usize) -> Result<&'a [u8]> {
    let len = read_u64(buf, pos)? as usize;
    let end = pos
        .checked_add(len)
        .ok_or_else(|| Error::Corrupt("length overflow".into()))?;
    if end > buf.len() {
        return Err(Error::Corrupt(format!(
            "length-prefixed slice of {len} bytes exceeds buffer"
        )));
    }
    let s = &buf[*pos..end];
    *pos = end;
    Ok(s)
}

/// Append an f64 (LE bytes).
pub fn write_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Read an f64.
pub fn read_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    let end = *pos + 8;
    if end > buf.len() {
        return Err(Error::Corrupt("f64: unexpected end of buffer".into()));
    }
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[*pos..end]);
    *pos = end;
    Ok(f64::from_le_bytes(b))
}

/// Append a UTF-8 string (length-prefixed).
pub fn write_str(out: &mut Vec<u8>, s: &str) {
    write_bytes(out, s.as_bytes());
}

/// Read a UTF-8 string.
pub fn read_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let bytes = read_bytes(buf, pos)?;
    String::from_utf8(bytes.to_vec())
        .map_err(|_| Error::Corrupt("invalid utf-8 in string".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn truncated_varint_errors() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX);
        buf.pop();
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn ten_byte_varint_rejects_out_of_width_bits() {
        // u64::MAX is the canonical 10-byte case: nine continuation
        // bytes carrying 63 bits + a final 0x01 carrying bit 63.
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX);
        assert_eq!(buf.len(), 10);
        assert_eq!(buf[9], 0x01);
        let mut pos = 0;
        assert_eq!(read_u64(&buf, &mut pos).unwrap(), u64::MAX);
        // 10th-byte payload bits above bit 63 used to be shifted out
        // silently (aliasing distinct encodings); now they are errors.
        for tenth in [0x02u8, 0x03, 0x42, 0x7F] {
            let mut bad = buf.clone();
            bad[9] = tenth;
            let mut pos = 0;
            assert!(read_u64(&bad, &mut pos).is_err(), "10th byte {tenth:#x} accepted");
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let mut buf = Vec::new();
        write_bytes(&mut buf, b"hello");
        write_bytes(&mut buf, b"");
        let mut pos = 0;
        assert_eq!(read_bytes(&buf, &mut pos).unwrap(), b"hello");
        assert_eq!(read_bytes(&buf, &mut pos).unwrap(), b"");
    }

    #[test]
    fn oversized_length_errors() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 1_000_000);
        buf.extend_from_slice(b"short");
        let mut pos = 0;
        assert!(read_bytes(&buf, &mut pos).is_err());
    }

    #[test]
    fn str_and_f64_roundtrip() {
        let mut buf = Vec::new();
        write_str(&mut buf, "CLDHGH");
        write_f64(&mut buf, -1.25e-7);
        let mut pos = 0;
        assert_eq!(read_str(&buf, &mut pos).unwrap(), "CLDHGH");
        assert_eq!(read_f64(&buf, &mut pos).unwrap(), -1.25e-7);
    }
}
