//! LSB-first bit streams over byte buffers.
//!
//! Word-buffered writer/reader: bits accumulate in a `u64`; flushes are
//! 8-byte aligned on the fast path. LSB-first ordering matches ZFP's
//! stream convention, which keeps the embedded coder's group tests
//! cheap (`x >>= 1` walks the stream order).

/// Append-only bit writer (LSB-first within each byte).
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, LSB-first.
    acc: u64,
    /// Number of valid bits in `acc` (0..64).
    nbits: u32,
    /// Total bits written (for bit-rate accounting).
    total_bits: u64,
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl BitWriter {
    pub fn new() -> Self {
        BitWriter { buf: Vec::new(), acc: 0, nbits: 0, total_bits: 0 }
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0, total_bits: 0 }
    }

    /// Total number of bits written so far.
    #[inline]
    pub fn bit_len(&self) -> u64 {
        self.total_bits
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.acc |= (bit as u64) << self.nbits;
        self.nbits += 1;
        self.total_bits += 1;
        if self.nbits == 64 {
            self.flush_word();
        }
    }

    /// Write the low `n` bits of `v` (n ≤ 64), LSB-first.
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        self.total_bits += n as u64;
        let room = 64 - self.nbits;
        if n < room {
            self.acc |= v << self.nbits;
            self.nbits += n;
        } else {
            self.acc |= v << self.nbits; // low `room` bits land here (shift overflow is masked by u64)
            let acc = self.acc;
            self.buf.extend_from_slice(&acc.to_le_bytes());
            self.acc = if room == 64 { 0 } else { v >> room };
            self.nbits = n - room;
        }
    }

    #[inline]
    fn flush_word(&mut self) {
        self.buf.extend_from_slice(&self.acc.to_le_bytes());
        self.acc = 0;
        self.nbits = 0;
    }

    /// Finish the stream, returning the backing bytes (zero-padded to a
    /// byte boundary).
    pub fn finish(mut self) -> Vec<u8> {
        while self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
        self.buf
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next byte index to refill from.
    pos: usize,
    acc: u64,
    nbits: u32,
    total_read: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, nbits: 0, total_read: 0 }
    }

    /// Number of bits consumed so far.
    #[inline]
    pub fn bits_read(&self) -> u64 {
        self.total_read
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: one unaligned 8-byte load fills as many whole
        // bytes as fit above the pending bits.
        if self.pos + 8 <= self.buf.len() {
            let chunk = u64::from_le_bytes(
                self.buf[self.pos..self.pos + 8].try_into().unwrap(),
            );
            let take = (64 - self.nbits) >> 3; // whole bytes that fit
            if take == 0 {
                return;
            }
            let bits = 8 * take;
            // Mask to the consumed bytes only — the tail byte must not
            // leak partial bits into the accumulator.
            let masked = if bits >= 64 { chunk } else { chunk & ((1u64 << bits) - 1) };
            self.acc |= masked << self.nbits;
            self.pos += take as usize;
            self.nbits += bits;
            return;
        }
        while self.nbits <= 56 && self.pos < self.buf.len() {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read one bit. Reads past the end return 0 (zero-padding
    /// semantics, matching the writer's `finish`).
    #[inline]
    pub fn read_bit(&mut self) -> bool {
        if self.nbits == 0 {
            self.refill();
            if self.nbits == 0 {
                self.total_read += 1;
                return false;
            }
        }
        let bit = self.acc & 1 != 0;
        self.acc >>= 1;
        self.nbits -= 1;
        self.total_read += 1;
        bit
    }

    /// Peek at the next `n` bits (n ≤ 56) without consuming (LSB-first;
    /// bits past the end of the stream read as zero). Used by the
    /// table-driven Huffman decoder and the embedded coder's run scans.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 56);
        if self.nbits < n {
            self.refill();
        }
        self.acc & ((1u64 << n) - 1)
    }

    /// Peek at the next 12 bits without consuming.
    #[inline]
    pub fn peek12(&mut self) -> u32 {
        self.peek_bits(12) as u32
    }

    /// Consume `n` bits previously examined via a peek (n ≤ 56).
    /// Consuming past the end is allowed (zero-padding semantics) and
    /// only advances the counters.
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(n <= 56);
        self.total_read += n as u64;
        let take = n.min(self.nbits);
        self.acc >>= take;
        self.nbits -= take;
    }

    /// Read `n` bits (n ≤ 57 fast path; up to 64 supported).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 64);
        if n == 0 {
            return 0;
        }
        if n <= 57 {
            if self.nbits < n {
                self.refill();
            }
            let avail = self.nbits.min(n);
            let mask = if avail == 64 { u64::MAX } else { (1u64 << avail) - 1 };
            let v = self.acc & mask;
            self.acc >>= avail;
            self.nbits -= avail;
            self.total_read += n as u64;
            // Past-the-end bits read as zero.
            v
        } else {
            let lo = self.read_bits(32);
            let hi = self.read_bits(n - 32);
            lo | (hi << 32)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    #[test]
    fn roundtrip_single_bits() {
        let mut w = BitWriter::new();
        let pattern: Vec<bool> = (0..131).map(|i| i % 3 == 0).collect();
        for &b in &pattern {
            w.write_bit(b);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let mut rng = Rng::new(11);
        let items: Vec<(u64, u32)> = (0..2000)
            .map(|_| {
                let n = rng.range(1, 65) as u32;
                let v = rng.next_u64() & if n == 64 { u64::MAX } else { (1 << n) - 1 };
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let expected_bits: u64 = items.iter().map(|&(_, n)| n as u64).sum();
        assert_eq!(w.bit_len(), expected_bits);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n), v, "width {n}");
        }
    }

    #[test]
    fn read_past_end_is_zero() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        // The rest of the padded byte and beyond reads as zeros.
        assert_eq!(r.read_bits(64), 0);
        assert!(!r.read_bit());
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bits(0xFFFF, 16);
        assert_eq!(w.bit_len(), 17);
    }

    #[test]
    fn interleaved_bit_and_word_writes() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bits(0xDEADBEEF, 32);
        w.write_bit(false);
        w.write_bits(0x123456789ABCDEF0, 64);
        w.write_bits(0x7F, 7);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert_eq!(r.read_bits(32), 0xDEADBEEF);
        assert!(!r.read_bit());
        assert_eq!(r.read_bits(64), 0x123456789ABCDEF0);
        assert_eq!(r.read_bits(7), 0x7F);
    }
}
