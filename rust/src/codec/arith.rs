//! Range (arithmetic) coder — the paper's alternative Stage-III
//! entropy coder (ref [48], Witten–Neal–Cleary). Static-frequency
//! variant: the symbol table is serialized like the Huffman table and
//! both sides drive the same cumulative-frequency model.
//!
//! Purpose in this repo: quantify the Huffman-vs-entropy gap that the
//! paper's +0.5 bit/value offset models (`cargo bench --bench
//! ablations`, Stage-III ablation) — a range coder reaches the Shannon
//! bound to within ~0.01 bit/value at the cost of slower coding.

use super::varint;
use crate::{Error, Result};

const TOP: u64 = 1 << 48;
const BOT: u64 = 1 << 40;

/// Static frequency model over a dense symbol alphabet.
#[derive(Clone, Debug)]
pub struct FreqModel {
    /// Sorted symbols.
    syms: Vec<u32>,
    /// Scaled frequencies (same order as `syms`), each ≥ 1.
    freqs: Vec<u32>,
    /// Cumulative frequencies, len = syms.len() + 1.
    cum: Vec<u32>,
}

/// Total frequency scale (16-bit keeps the coder exact in u64).
const SCALE_BITS: u32 = 16;

impl FreqModel {
    /// Build from raw counts, rescaling to a 2^16 total.
    pub fn from_counts(counts: &[(u32, u64)]) -> Result<FreqModel> {
        if counts.is_empty() {
            return Err(Error::InvalidArg("arith: empty alphabet".into()));
        }
        let mut counts: Vec<(u32, u64)> = counts.iter().filter(|&&(_, c)| c > 0).copied().collect();
        counts.sort_unstable();
        let total: u64 = counts.iter().map(|&(_, c)| c).sum();
        let target = 1u64 << SCALE_BITS;
        if (counts.len() as u64) > target {
            return Err(Error::InvalidArg("arith: alphabet too large".into()));
        }
        // Scale with floor + largest-remainder repair, every symbol ≥ 1.
        let mut freqs: Vec<u32> = counts
            .iter()
            .map(|&(_, c)| (((c as u128 * target as u128) / total as u128) as u32).max(1))
            .collect();
        let mut sum: i64 = freqs.iter().map(|&f| f as i64).sum();
        // Repair to exact target by adjusting the largest entries.
        while sum != target as i64 {
            let step = if sum > target as i64 { -1i64 } else { 1 };
            let idx = if step < 0 {
                // take from the largest (> 1)
                freqs
                    .iter()
                    .enumerate()
                    .filter(|(_, &f)| f > 1)
                    .max_by_key(|(_, &f)| f)
                    .map(|(i, _)| i)
                    .ok_or_else(|| Error::Other("arith: cannot rescale".into()))?
            } else {
                freqs.iter().enumerate().max_by_key(|(_, &f)| f).map(|(i, _)| i).unwrap()
            };
            freqs[idx] = (freqs[idx] as i64 + step) as u32;
            sum += step;
        }
        let mut cum = Vec::with_capacity(freqs.len() + 1);
        let mut acc = 0u32;
        cum.push(0);
        for &f in &freqs {
            acc += f;
            cum.push(acc);
        }
        Ok(FreqModel { syms: counts.iter().map(|&(s, _)| s).collect(), freqs, cum })
    }

    pub fn from_symbols(symbols: &[u32]) -> Result<FreqModel> {
        let mut counts = std::collections::HashMap::new();
        for &s in symbols {
            *counts.entry(s).or_insert(0u64) += 1;
        }
        let mut v: Vec<(u32, u64)> = counts.into_iter().collect();
        v.sort_unstable();
        FreqModel::from_counts(&v)
    }

    fn index_of(&self, sym: u32) -> Option<usize> {
        self.syms.binary_search(&sym).ok()
    }

    /// Find the symbol index whose cumulative range contains `f`.
    fn find(&self, f: u32) -> usize {
        // cum is sorted; partition_point gives first cum[i+1] > f.
        self.cum.partition_point(|&c| c <= f) - 1
    }

    /// Serialize (symbols delta-coded + scaled freqs).
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write_u64(&mut out, self.syms.len() as u64);
        let mut prev = 0u32;
        for (&s, &f) in self.syms.iter().zip(&self.freqs) {
            varint::write_u64(&mut out, (s - prev) as u64);
            varint::write_u64(&mut out, f as u64);
            prev = s;
        }
        out
    }

    pub fn deserialize(buf: &[u8], pos: &mut usize) -> Result<FreqModel> {
        let n = varint::read_u64(buf, pos)? as usize;
        if n == 0 {
            return Err(Error::Corrupt("arith: empty model".into()));
        }
        let mut syms = Vec::with_capacity(n);
        let mut freqs = Vec::with_capacity(n);
        let mut prev = 0u32;
        for _ in 0..n {
            prev = prev
                .checked_add(varint::read_u64(buf, pos)? as u32)
                .ok_or_else(|| Error::Corrupt("arith: symbol overflow".into()))?;
            let f = varint::read_u64(buf, pos)? as u32;
            if f == 0 {
                return Err(Error::Corrupt("arith: zero frequency".into()));
            }
            syms.push(prev);
            freqs.push(f);
        }
        let total: u64 = freqs.iter().map(|&f| f as u64).sum();
        if total != 1 << SCALE_BITS {
            return Err(Error::Corrupt(format!("arith: bad total {total}")));
        }
        let mut cum = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        cum.push(0);
        for &f in &freqs {
            acc += f;
            cum.push(acc);
        }
        Ok(FreqModel { syms, freqs, cum })
    }
}

/// Encode a symbol stream with a static model. Output framing:
/// varint count ‖ model ‖ code bytes.
pub fn encode(symbols: &[u32]) -> Result<Vec<u8>> {
    let model = FreqModel::from_symbols(symbols)?;
    let mut out = Vec::new();
    varint::write_u64(&mut out, symbols.len() as u64);
    varint::write_bytes(&mut out, &model.serialize());

    let mut code = Vec::with_capacity(symbols.len() / 4);
    let mut low: u64 = 0;
    let mut range: u64 = u64::MAX;
    for &s in symbols {
        let i = model
            .index_of(s)
            .ok_or_else(|| Error::InvalidArg(format!("arith: unknown symbol {s}")))?;
        let (c_lo, c_hi) = (model.cum[i] as u64, model.cum[i + 1] as u64);
        range >>= SCALE_BITS;
        low = low.wrapping_add(c_lo * range);
        range *= c_hi - c_lo;
        // Renormalize: emit top bytes while determined, handle carry
        // via the standard range-coder condition.
        while (low ^ low.wrapping_add(range)) < TOP || {
            if range < BOT {
                range = low.wrapping_neg() & (BOT - 1);
                true
            } else {
                false
            }
        } {
            code.push((low >> 56) as u8);
            low <<= 8;
            range <<= 8;
        }
    }
    // Flush.
    for _ in 0..8 {
        code.push((low >> 56) as u8);
        low <<= 8;
    }
    varint::write_bytes(&mut out, &code);
    Ok(out)
}

/// Decode a stream produced by [`encode`].
pub fn decode(buf: &[u8], pos: &mut usize) -> Result<Vec<u32>> {
    let n = varint::read_u64(buf, pos)? as usize;
    let mbytes = varint::read_bytes(buf, pos)?;
    let mut mpos = 0;
    let model = FreqModel::deserialize(mbytes, &mut mpos)?;
    let code = varint::read_bytes(buf, pos)?;

    let mut byte_idx = 0usize;
    let mut next_byte = || -> u64 {
        let b = code.get(byte_idx).copied().unwrap_or(0) as u64;
        byte_idx += 1;
        b
    };
    let mut low: u64 = 0;
    let mut range: u64 = u64::MAX;
    let mut value: u64 = 0;
    for _ in 0..8 {
        value = (value << 8) | next_byte();
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        range >>= SCALE_BITS;
        let f = ((value.wrapping_sub(low)) / range).min((1 << SCALE_BITS) - 1) as u32;
        let i = model.find(f);
        let (c_lo, c_hi) = (model.cum[i] as u64, model.cum[i + 1] as u64);
        low = low.wrapping_add(c_lo * range);
        range *= c_hi - c_lo;
        out.push(model.syms[i]);
        while (low ^ low.wrapping_add(range)) < TOP || {
            if range < BOT {
                range = low.wrapping_neg() & (BOT - 1);
                true
            } else {
                false
            }
        } {
            value = (value << 8) | next_byte();
            low <<= 8;
            range <<= 8;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn roundtrip(symbols: &[u32]) -> usize {
        let enc = encode(symbols).unwrap();
        let mut pos = 0;
        let dec = decode(&enc, &mut pos).unwrap();
        assert_eq!(dec, symbols);
        enc.len()
    }

    #[test]
    fn roundtrip_small() {
        roundtrip(&[1, 2, 3, 1, 1, 1, 2, 5, 5, 5, 5, 5, 9]);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let n = roundtrip(&[7; 10_000]);
        assert!(n < 200, "single-symbol stream should be near-free: {n}");
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Rng::new(191);
        let syms: Vec<u32> = (0..30_000)
            .map(|_| (32768.0 + rng.gauss() * 40.0) as u32)
            .collect();
        roundtrip(&syms);
    }

    #[test]
    fn beats_huffman_toward_entropy() {
        // A p=0.9/0.1 binary source: H = 0.469 bits. Huffman needs 1
        // bit/symbol; the range coder should get within 2%.
        let mut rng = Rng::new(192);
        let syms: Vec<u32> = (0..100_000).map(|_| rng.bool(0.9) as u32).collect();
        let arith_len = roundtrip(&syms);
        let huff = crate::sz::huffman_stage::encode_symbols(&syms).unwrap();
        assert!(
            arith_len * 2 < huff.len(),
            "arith {arith_len} should be far below huffman {}",
            huff.len()
        );
        let rate = arith_len as f64 * 8.0 / syms.len() as f64;
        assert!(rate < 0.52, "rate {rate} should approach H=0.469");
    }

    #[test]
    fn unknown_alphabet_ok_large() {
        let mut rng = Rng::new(193);
        // 5000 distinct symbols, skewed.
        let syms: Vec<u32> = (0..50_000)
            .map(|_| {
                let u = rng.f64();
                (5000.0 * u * u) as u32
            })
            .collect();
        roundtrip(&syms);
    }

    #[test]
    fn corrupt_model_rejected() {
        let enc = encode(&[1, 2, 3]).unwrap();
        assert!(decode(&enc[..4], &mut 0).is_err());
    }
}
