//! Canonical Huffman coding over `u32` symbols.
//!
//! This is the Stage-III entropy coder used by the SZ reimplementation
//! (quantization-bin indices, up to 65,535 symbols plus an escape
//! symbol). Codes are canonical so the table serializes as
//! `(symbol, length)` pairs only; code length is capped at
//! [`MAX_CODE_LEN`] via the standard depth-limiting rebalance
//! (package-merge-lite: scale counts until the tree fits).
//!
//! Decoding is canonical limit-search: O(length) per symbol with a
//! first-code/offset table per length, accelerated by a direct
//! 12-bit-prefix lookup for short codes (the common case — hot-path
//! optimization, see EXPERIMENTS.md §Perf).

use super::bitstream::{BitReader, BitWriter};
use super::varint;
use crate::{Error, Result};

/// Maximum code length. 32 keeps codes in a u32 and the decoder simple;
/// depth-limiting only triggers on pathological distributions.
pub const MAX_CODE_LEN: u32 = 32;

/// Width of the fast decoder prefix table (2^12 entries = 4096).
const FAST_BITS: u32 = 12;

/// Build-side encoder: symbol → (code, length).
pub struct HuffmanEncoder {
    /// Sparse map from symbol to (canonical code value, bit length).
    codes: Vec<(u32, u32, u32)>, // (symbol, code, len), sorted by symbol
}

/// One entry of the serialized table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct SymLen {
    sym: u32,
    len: u32,
}

/// Compute Huffman code lengths from frequencies using the classic
/// two-queue/heap algorithm, then depth-limit to `MAX_CODE_LEN`.
fn code_lengths(freqs: &[(u32, u64)]) -> Vec<SymLen> {
    assert!(!freqs.is_empty());
    if freqs.len() == 1 {
        return vec![SymLen { sym: freqs[0].0, len: 1 }];
    }

    // Heap of (weight, node_id); internal nodes get ids >= n.
    #[derive(PartialEq, Eq)]
    struct Node(u64, usize);
    impl Ord for Node {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // Min-heap via reversed compare; tie-break on id for determinism.
            other.0.cmp(&self.0).then(other.1.cmp(&self.1))
        }
    }
    impl PartialOrd for Node {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let n = freqs.len();
    let mut heap = std::collections::BinaryHeap::with_capacity(n);
    // parent[i] for all tree nodes; leaves are 0..n.
    let mut parent = vec![usize::MAX; 2 * n - 1];
    for (i, &(_, f)) in freqs.iter().enumerate() {
        heap.push(Node(f.max(1), i));
    }
    let mut next_id = n;
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        parent[a.1] = next_id;
        parent[b.1] = next_id;
        heap.push(Node(a.0 + b.0, next_id));
        next_id += 1;
    }

    // Depth of each leaf = path length to root.
    let mut lens: Vec<SymLen> = freqs
        .iter()
        .enumerate()
        .map(|(i, &(sym, _))| {
            let mut d = 0u32;
            let mut j = i;
            while parent[j] != usize::MAX {
                j = parent[j];
                d += 1;
            }
            SymLen { sym, len: d }
        })
        .collect();

    // Depth-limit: push over-long codes up, compensating by pushing the
    // most shallow deep-enough codes down (Kraft-sum repair).
    if lens.iter().any(|sl| sl.len > MAX_CODE_LEN) {
        // Kraft units in terms of 2^-MAX_CODE_LEN.
        let unit = |len: u32| 1u64 << (MAX_CODE_LEN - len.min(MAX_CODE_LEN));
        let budget = 1u64 << MAX_CODE_LEN;
        for sl in lens.iter_mut() {
            if sl.len > MAX_CODE_LEN {
                sl.len = MAX_CODE_LEN;
            }
        }
        let mut used: u64 = lens.iter().map(|sl| unit(sl.len)).sum();
        // Lengthen the shortest codes until the Kraft inequality holds.
        while used > budget {
            // Find a symbol with smallest length < MAX_CODE_LEN whose
            // lengthening reclaims the most.
            let idx = lens
                .iter()
                .enumerate()
                .filter(|(_, sl)| sl.len < MAX_CODE_LEN)
                .min_by_key(|(_, sl)| sl.len)
                .map(|(i, _)| i)
                .expect("kraft repair: no lengthenable code");
            used -= unit(lens[idx].len) - unit(lens[idx].len + 1);
            lens[idx].len += 1;
        }
    }
    lens
}

/// Assign canonical codes given (symbol, length) pairs.
/// Canonical order: shorter lengths first, ties by symbol value.
fn canonical_codes(mut lens: Vec<SymLen>) -> Vec<(u32, u32, u32)> {
    lens.sort_by_key(|sl| (sl.len, sl.sym));
    let mut out = Vec::with_capacity(lens.len());
    let mut code: u32 = 0;
    let mut prev_len = 0u32;
    for sl in &lens {
        code <<= sl.len - prev_len;
        out.push((sl.sym, code, sl.len));
        prev_len = sl.len;
        code = code.wrapping_add(1);
    }
    out.sort_by_key(|&(sym, _, _)| sym);
    out
}

impl HuffmanEncoder {
    /// Build an encoder from symbol frequencies (`(symbol, count)`,
    /// zero-count symbols may be omitted).
    pub fn from_freqs(freqs: &[(u32, u64)]) -> Result<Self> {
        if freqs.is_empty() {
            return Err(Error::InvalidArg("huffman: empty alphabet".into()));
        }
        let lens = code_lengths(freqs);
        Ok(HuffmanEncoder { codes: canonical_codes(lens) })
    }

    /// Build from a raw symbol stream (counts computed internally).
    /// Dense counting for small alphabets (quantization bins) — ~10×
    /// faster than hash-map counting on multi-megabyte streams.
    pub fn from_symbols(symbols: &[u32]) -> Result<Self> {
        let max_sym = symbols.iter().copied().max().unwrap_or(0);
        let freqs: Vec<(u32, u64)> = if (max_sym as usize) < 1 << 20 {
            let mut counts = vec![0u64; max_sym as usize + 1];
            for &s in symbols {
                counts[s as usize] += 1;
            }
            counts
                .into_iter()
                .enumerate()
                .filter(|&(_, c)| c > 0)
                .map(|(s, c)| (s as u32, c))
                .collect()
        } else {
            let mut counts = std::collections::HashMap::new();
            for &s in symbols {
                *counts.entry(s).or_insert(0u64) += 1;
            }
            let mut v: Vec<(u32, u64)> = counts.into_iter().collect();
            v.sort_unstable();
            v
        };
        Self::from_freqs(&freqs)
    }

    /// Look up (code, len) for a symbol.
    #[inline]
    pub fn code(&self, sym: u32) -> Option<(u32, u32)> {
        self.codes
            .binary_search_by_key(&sym, |&(s, _, _)| s)
            .ok()
            .map(|i| (self.codes[i].1, self.codes[i].2))
    }

    /// Encode a symbol stream into `w`. Errors on unknown symbols.
    pub fn encode(&self, symbols: &[u32], w: &mut BitWriter) -> Result<()> {
        // Dense LUT when the alphabet is contiguous-ish (quant bins are):
        // symbol -> (code,len), avoiding the binary search per symbol.
        let max_sym = self.codes.last().map(|&(s, _, _)| s).unwrap_or(0);
        if (max_sym as usize) < 1 << 20 {
            let mut lut: Vec<(u32, u32)> = vec![(0, 0); max_sym as usize + 1];
            for &(s, c, l) in &self.codes {
                lut[s as usize] = (c, l);
            }
            for &s in symbols {
                let (code, len) = *lut
                    .get(s as usize)
                    .filter(|&&(_, l)| l > 0)
                    .ok_or_else(|| Error::InvalidArg(format!("huffman: unknown symbol {s}")))?;
                // Canonical codes are MSB-first; emit reversed for the
                // LSB-first stream.
                w.write_bits((code.reverse_bits() >> (32 - len)) as u64, len);
            }
        } else {
            for &s in symbols {
                let (code, len) = self
                    .code(s)
                    .ok_or_else(|| Error::InvalidArg(format!("huffman: unknown symbol {s}")))?;
                w.write_bits((code.reverse_bits() >> (32 - len)) as u64, len);
            }
        }
        Ok(())
    }

    /// Serialize the code table: varint count, then (symbol, len) pairs
    /// (delta-coded symbols).
    pub fn serialize_table(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write_u64(&mut out, self.codes.len() as u64);
        let mut prev = 0u32;
        for &(sym, _, len) in &self.codes {
            varint::write_u64(&mut out, (sym - prev) as u64);
            varint::write_u64(&mut out, len as u64);
            prev = sym;
        }
        out
    }

    /// Expected bit-length of a stream with these counts (for tests /
    /// estimation cross-checks).
    pub fn expected_bits(&self, freqs: &[(u32, u64)]) -> u64 {
        freqs
            .iter()
            .map(|&(s, f)| f * self.code(s).map(|(_, l)| l as u64).unwrap_or(0))
            .sum()
    }
}

/// Decoder built from a serialized canonical table.
pub struct HuffmanDecoder {
    /// Sorted by (len, sym): canonical order.
    syms: Vec<u32>,
    /// first_code[l] = first canonical code of length l (MSB-first value).
    first_code: [u32; (MAX_CODE_LEN + 1) as usize],
    /// first_index[l] = index into `syms` of the first length-l code.
    first_index: [u32; (MAX_CODE_LEN + 1) as usize],
    /// count[l] = number of codes of length l.
    count: [u32; (MAX_CODE_LEN + 1) as usize],
    /// Fast path: FAST_BITS-wide LSB-first prefix -> (symbol, len) when
    /// len <= FAST_BITS, else len = 0 sentinel.
    fast: Vec<(u32, u8)>,
}

impl HuffmanDecoder {
    /// Deserialize a table produced by [`HuffmanEncoder::serialize_table`].
    pub fn deserialize_table(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let n = varint::read_u64(buf, pos)? as usize;
        if n == 0 {
            return Err(Error::Corrupt("huffman: empty table".into()));
        }
        // Untrusted entry count: each entry is >= 2 bytes (two
        // varints), so cap the preallocation by what the buffer could
        // possibly hold — the read loop errors out on truncation.
        let mut lens = Vec::with_capacity(n.min(buf.len() / 2 + 1));
        let mut prev = 0u32;
        for _ in 0..n {
            let dsym = varint::read_u64(buf, pos)? as u32;
            let len = varint::read_u64(buf, pos)? as u32;
            if len == 0 || len > MAX_CODE_LEN {
                return Err(Error::Corrupt(format!("huffman: bad code length {len}")));
            }
            prev = prev
                .checked_add(dsym)
                .ok_or_else(|| Error::Corrupt("huffman: symbol overflow".into()))?;
            lens.push(SymLen { sym: prev, len });
            prev = prev.wrapping_add(0); // symbols strictly increasing via delta >= 0
        }
        Self::from_lengths(lens)
    }

    fn from_lengths(mut lens: Vec<SymLen>) -> Result<Self> {
        lens.sort_by_key(|sl| (sl.len, sl.sym));
        let mut count = [0u32; (MAX_CODE_LEN + 1) as usize];
        for sl in &lens {
            count[sl.len as usize] += 1;
        }
        // Kraft check.
        let mut kraft: u64 = 0;
        for l in 1..=MAX_CODE_LEN {
            kraft += (count[l as usize] as u64) << (MAX_CODE_LEN - l);
        }
        if kraft > 1u64 << MAX_CODE_LEN {
            return Err(Error::Corrupt("huffman: over-subscribed code".into()));
        }
        let mut first_code = [0u32; (MAX_CODE_LEN + 1) as usize];
        let mut first_index = [0u32; (MAX_CODE_LEN + 1) as usize];
        let mut code = 0u32;
        let mut index = 0u32;
        for l in 1..=MAX_CODE_LEN as usize {
            code <<= 1;
            first_code[l] = code;
            first_index[l] = index;
            code = code.wrapping_add(count[l]);
            index += count[l];
        }
        let syms: Vec<u32> = lens.iter().map(|sl| sl.sym).collect();

        // Build the fast prefix table.
        let mut fast = vec![(0u32, 0u8); 1 << FAST_BITS];
        {
            let mut code = 0u32;
            let mut idx = 0usize;
            for l in 1..=MAX_CODE_LEN {
                code <<= 1;
                for _ in 0..count[l as usize] {
                    if l <= FAST_BITS {
                        // LSB-first stream: the code arrives bit-reversed.
                        let rev = code.reverse_bits() >> (32 - l);
                        let step = 1u32 << l;
                        let mut p = rev;
                        while p < (1 << FAST_BITS) {
                            fast[p as usize] = (syms[idx], l as u8);
                            p += step;
                        }
                    }
                    code = code.wrapping_add(1);
                    idx += 1;
                }
            }
        }

        Ok(HuffmanDecoder { syms, first_code, first_index, count, fast })
    }

    /// Decode `n` symbols from `r`.
    pub fn decode(&self, r: &mut BitReader, n: usize, out: &mut Vec<u32>) -> Result<()> {
        out.reserve(n);
        for _ in 0..n {
            out.push(self.decode_one(r)?);
        }
        Ok(())
    }

    /// Decode a single symbol. Fast path: 12-bit prefix lookup (covers
    /// all codes ≤ 12 bits — the overwhelming majority for peaked
    /// quantization-symbol distributions); falls back to canonical
    /// limit-search for longer codes.
    #[inline]
    pub fn decode_one(&self, r: &mut BitReader) -> Result<u32> {
        let (sym, len) = self.fast_lookup(r.peek12());
        if len != 0 {
            r.consume(len as u32);
            return Ok(sym);
        }
        self.decode_one_slow(r)
    }

    /// Canonical limit-search, bit by bit (MSB-first code value
    /// accumulated from the LSB-first stream).
    fn decode_one_slow(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u32;
        for len in 1..=MAX_CODE_LEN {
            code = (code << 1) | r.read_bit() as u32;
            let l = len as usize;
            if self.count[l] > 0 {
                let offset = code.wrapping_sub(self.first_code[l]);
                if offset < self.count[l] {
                    return Ok(self.syms[(self.first_index[l] + offset) as usize]);
                }
            }
        }
        Err(Error::Corrupt("huffman: invalid code in stream".into()))
    }

    /// Number of symbols in the alphabet.
    pub fn alphabet_len(&self) -> usize {
        self.syms.len()
    }

    /// Fast-table accessor.
    #[inline]
    fn fast_lookup(&self, prefix: u32) -> (u32, u8) {
        self.fast[(prefix & ((1 << FAST_BITS) - 1)) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Rng;

    fn roundtrip(symbols: &[u32]) {
        let enc = HuffmanEncoder::from_symbols(symbols).unwrap();
        let mut w = BitWriter::new();
        enc.encode(symbols, &mut w).unwrap();
        let table = enc.serialize_table();
        let bytes = w.finish();

        let mut pos = 0;
        let dec = HuffmanDecoder::deserialize_table(&table, &mut pos).unwrap();
        assert_eq!(pos, table.len());
        let mut r = BitReader::new(&bytes);
        let mut out = Vec::new();
        dec.decode(&mut r, symbols.len(), &mut out).unwrap();
        assert_eq!(out, symbols);
    }

    #[test]
    fn roundtrip_small() {
        roundtrip(&[1, 2, 3, 1, 1, 1, 2, 5, 5, 5, 5, 5, 9]);
    }

    #[test]
    fn roundtrip_single_symbol() {
        roundtrip(&[42; 100]);
    }

    #[test]
    fn roundtrip_two_symbols() {
        roundtrip(&[0, 1, 0, 0, 0, 1, 1, 0]);
    }

    #[test]
    fn roundtrip_random_large_alphabet() {
        let mut rng = Rng::new(21);
        // Zipf-ish distribution over 5000 symbols (like quant bins).
        let symbols: Vec<u32> = (0..50_000)
            .map(|_| {
                let u = rng.f64();
                (5000.0 * u * u * u) as u32
            })
            .collect();
        roundtrip(&symbols);
    }

    #[test]
    fn near_entropy_bitrate() {
        // A strongly skewed distribution should compress near entropy.
        let mut rng = Rng::new(22);
        let symbols: Vec<u32> = (0..100_000)
            .map(|_| if rng.bool(0.9) { 0 } else { rng.range(1, 16) as u32 })
            .collect();
        let enc = HuffmanEncoder::from_symbols(&symbols).unwrap();
        let mut w = BitWriter::new();
        enc.encode(&symbols, &mut w).unwrap();
        let bits = w.bit_len() as f64;
        // entropy of the empirical distribution
        let mut counts = std::collections::HashMap::new();
        for &s in &symbols {
            *counts.entry(s).or_insert(0u64) += 1;
        }
        let n = symbols.len() as f64;
        let entropy: f64 = counts
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        let actual_rate = bits / n;
        assert!(actual_rate >= entropy - 1e-9, "huffman beat entropy?");
        assert!(
            actual_rate <= entropy + 1.0,
            "rate {actual_rate} far above entropy {entropy}"
        );
    }

    #[test]
    fn unknown_symbol_errors() {
        let enc = HuffmanEncoder::from_symbols(&[1, 2, 3]).unwrap();
        let mut w = BitWriter::new();
        assert!(enc.encode(&[99], &mut w).is_err());
    }

    #[test]
    fn corrupt_table_errors() {
        // Length 0 is invalid.
        let mut buf = Vec::new();
        varint::write_u64(&mut buf, 1);
        varint::write_u64(&mut buf, 5);
        varint::write_u64(&mut buf, 0);
        let mut pos = 0;
        assert!(HuffmanDecoder::deserialize_table(&buf, &mut pos).is_err());
    }

    #[test]
    fn expected_bits_matches_actual() {
        let symbols = vec![7u32, 7, 7, 8, 8, 9, 10, 10, 10, 10];
        let mut freqs = std::collections::HashMap::new();
        for &s in &symbols {
            *freqs.entry(s).or_insert(0u64) += 1;
        }
        let mut freqs: Vec<(u32, u64)> = freqs.into_iter().collect();
        freqs.sort_unstable();
        let enc = HuffmanEncoder::from_freqs(&freqs).unwrap();
        let mut w = BitWriter::new();
        enc.encode(&symbols, &mut w).unwrap();
        assert_eq!(enc.expected_bits(&freqs), w.bit_len());
    }
}
