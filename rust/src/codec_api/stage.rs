//! Composable codec stages (DESIGN.md §15).
//!
//! A [`Pipeline`](super::Pipeline) chains three kinds of stage, the
//! decomposition zarrs uses for its codec chains:
//!
//! * **array→array pre-stages** ([`ArrayStage`]): transform the f32
//!   field before the core coder sees it. Lossy pre-stages (bit
//!   rounding) consume part of the pipeline's error budget; lossless
//!   ones (the standalone delta/Lorenzo transform) must be inverted
//!   bit-exactly, which constrains what may follow them.
//! * **array→bytes core codecs**: the existing [`Codec`](super::Codec)
//!   impls (SZ, ZFP, DCT, raw), unchanged.
//! * **bytes→bytes post-stages** ([`BytesStage`]): reversible byte
//!   transforms over the core stream — byte shuffle, Huffman, the
//!   range coder.
//!
//! Every pre-stage emits a per-chunk *config blob* (possibly empty)
//! that its inverse needs; the pipeline frames the blobs ahead of the
//! core stream (varint length-prefixed, declared stage order) so a
//! truncated blob decodes as `Corrupt`, never a panic.

use crate::data::field::Dims;
use crate::sz::lorenzo;
use crate::{Error, Result};

/// An f32 array→array transform applied before the core codec.
///
/// `forward` mutates the buffer in place and returns the config blob
/// its `inverse` will need. `inverse` undoes the transform on the
/// decoded buffer and returns the (possibly corrected) dims — the raw
/// core codec reports `Dims::D1`, so a stage that records the true
/// shape in its blob (delta/Lorenzo) restores it here.
pub trait ArrayStage: Send + Sync {
    /// Short lowercase name, the token used in `--pipelines` specs.
    fn name(&self) -> &'static str;

    /// True if `inverse(forward(x)) == x` bit-exactly.
    fn lossless(&self) -> bool;

    /// True if this stage's inverse is only valid when every later
    /// stage (including the core codec) reproduces its output
    /// bit-exactly — the delta transform's running reconstruction
    /// diverges under any downstream loss.
    fn requires_exact_downstream(&self) -> bool {
        false
    }

    /// Apply the transform in place. `allowance` is this stage's share
    /// of the pipeline's absolute error budget (0 for lossless
    /// stages). Returns the config blob for [`ArrayStage::inverse`].
    fn forward(&self, data: &mut [f32], dims: Dims, allowance: f64) -> Result<Vec<u8>>;

    /// Undo the transform in place using the config blob recorded by
    /// `forward`. Returns the dims of the restored array.
    fn inverse(&self, data: &mut [f32], dims: Dims, cfg: &[u8]) -> Result<Dims>;
}

/// A reversible bytes→bytes transform applied after the core codec.
pub trait BytesStage: Send + Sync {
    /// Short lowercase name, the token used in `--pipelines` specs.
    fn name(&self) -> &'static str;

    fn forward(&self, bytes: &[u8]) -> Result<Vec<u8>>;

    fn inverse(&self, bytes: &[u8]) -> Result<Vec<u8>>;
}

/// Round every value to the lattice `q·Z`, `q = 2·allowance`, so the
/// stage's pointwise error is ≤ `allowance`. Rounding concentrates the
/// downstream prediction-error distribution onto lattice atoms (the
/// estimator's PDF transform models exactly this — see
/// `ErrorPdf::bitround`), which is what lets a plug-in entropy estimate
/// replace the extrapolated one on rough fields.
///
/// The quantization is evaluated in f64 with a per-value guard: if the
/// rounded value cast back to f32 lands outside the allowance (huge
/// magnitudes where one ulp exceeds the bound), the original value is
/// kept — correctness over smoothness.
#[derive(Clone, Copy, Debug, Default)]
pub struct BitRound;

impl ArrayStage for BitRound {
    fn name(&self) -> &'static str {
        "bitround"
    }

    fn lossless(&self) -> bool {
        false
    }

    fn forward(&self, data: &mut [f32], _dims: Dims, allowance: f64) -> Result<Vec<u8>> {
        if !(allowance > 0.0) || !allowance.is_finite() {
            return Err(Error::InvalidArg(format!(
                "bitround: allowance {allowance} must be positive and finite"
            )));
        }
        let q = 2.0 * allowance;
        for v in data.iter_mut() {
            let x = *v as f64;
            let r = ((x / q).round() * q) as f32;
            // NaN fails the comparison and is kept unchanged.
            if r.is_finite() && (r as f64 - x).abs() <= allowance {
                *v = r;
            }
        }
        Ok(Vec::new())
    }

    fn inverse(&self, _data: &mut [f32], dims: Dims, cfg: &[u8]) -> Result<Dims> {
        if !cfg.is_empty() {
            return Err(Error::Corrupt(format!(
                "bitround: unexpected {}-byte config blob",
                cfg.len()
            )));
        }
        Ok(dims)
    }
}

/// The SZ Lorenzo predictor lifted out as a standalone lossless
/// transform: each value is replaced by the *bit-pattern difference*
/// (wrapping u32 subtraction) between itself and its Lorenzo
/// prediction from already-scanned neighbors. Smooth fields turn into
/// near-zero-entropy residual planes that the byte-shuffle + entropy
/// post-stages exploit.
///
/// Exactness contract: predictions are IEEE f32 arithmetic over the
/// *original* neighbor values (forward walks the scan order backwards
/// so neighbors are still untouched; inverse walks forwards so they
/// are already restored), and the residual is pure bit arithmetic — so
/// the inverse is bit-exact, including NaN/Inf payloads, provided
/// every downstream stage is lossless. [`Pipeline`](super::Pipeline)
/// construction enforces that via
/// [`ArrayStage::requires_exact_downstream`].
///
/// The config blob records the field dims: the raw core codec's stream
/// is shapeless (`Dims::D1`), and the inverse needs the true shape to
/// re-run the predictor.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaLorenzo;

fn lorenzo_predict(data: &[f32], dims: Dims, i: usize) -> f32 {
    let e = dims.extents();
    match dims.ndim() {
        1 => lorenzo::predict_1d(data, i),
        2 => {
            let nx = e[2];
            lorenzo::predict_2d(data, nx, i / nx, i % nx)
        }
        _ => {
            let (ny, nx) = (e[1], e[2]);
            let plane = ny * nx;
            lorenzo::predict_3d(data, ny, nx, i / plane, (i % plane) / nx, i % nx)
        }
    }
}

impl ArrayStage for DeltaLorenzo {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn lossless(&self) -> bool {
        true
    }

    fn requires_exact_downstream(&self) -> bool {
        true
    }

    fn forward(&self, data: &mut [f32], dims: Dims, _allowance: f64) -> Result<Vec<u8>> {
        if dims.len() != data.len() {
            return Err(Error::InvalidArg(format!(
                "delta: dims {dims} disagree with {} values",
                data.len()
            )));
        }
        // Reverse scan order: predictions only reference lower indices,
        // which are still original when processed backwards.
        for i in (0..data.len()).rev() {
            let pred = lorenzo_predict(data, dims, i);
            data[i] = f32::from_bits(data[i].to_bits().wrapping_sub(pred.to_bits()));
        }
        let mut cfg = Vec::new();
        dims.encode(&mut cfg);
        Ok(cfg)
    }

    fn inverse(&self, data: &mut [f32], _dims: Dims, cfg: &[u8]) -> Result<Dims> {
        let mut pos = 0;
        let dims = Dims::decode(cfg, &mut pos)?;
        if pos != cfg.len() {
            return Err(Error::Corrupt("delta: trailing config bytes".into()));
        }
        if dims.len() != data.len() {
            return Err(Error::Corrupt(format!(
                "delta: config dims {dims} disagree with {} decoded values",
                data.len()
            )));
        }
        // Forward scan order: lower indices are already restored when a
        // prediction reads them.
        for i in 0..data.len() {
            let pred = lorenzo_predict(data, dims, i);
            data[i] = f32::from_bits(data[i].to_bits().wrapping_add(pred.to_bits()));
        }
        Ok(dims)
    }
}

/// Byte shuffle with stride 4 (one plane per f32 byte position): byte
/// `4j+k` of the input lands in plane `k`. Groups the
/// similarly-distributed residual bytes so a following entropy stage
/// sees four peaked distributions instead of one mixed one. A
/// non-multiple-of-4 tail is carried through verbatim after the planes.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShuffleBytes;

const SHUFFLE_STRIDE: usize = 4;

impl BytesStage for ShuffleBytes {
    fn name(&self) -> &'static str {
        "shuffle"
    }

    fn forward(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        let rows = bytes.len() / SHUFFLE_STRIDE;
        let mut out = Vec::with_capacity(bytes.len());
        for k in 0..SHUFFLE_STRIDE {
            for j in 0..rows {
                out.push(bytes[j * SHUFFLE_STRIDE + k]);
            }
        }
        out.extend_from_slice(&bytes[rows * SHUFFLE_STRIDE..]);
        Ok(out)
    }

    fn inverse(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        let rows = bytes.len() / SHUFFLE_STRIDE;
        let mut out = vec![0u8; bytes.len()];
        for k in 0..SHUFFLE_STRIDE {
            for j in 0..rows {
                out[j * SHUFFLE_STRIDE + k] = bytes[k * rows + j];
            }
        }
        out[rows * SHUFFLE_STRIDE..].copy_from_slice(&bytes[rows * SHUFFLE_STRIDE..]);
        Ok(out)
    }
}

/// Canonical Huffman over raw bytes — `sz/huffman_stage.rs` promoted
/// from an SZ-internal module to a registry post-stage. Empty input
/// passes through (the symbol coder needs a non-empty alphabet).
#[derive(Clone, Copy, Debug, Default)]
pub struct HuffBytes;

impl BytesStage for HuffBytes {
    fn name(&self) -> &'static str {
        "huff"
    }

    fn forward(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        if bytes.is_empty() {
            return Ok(Vec::new());
        }
        let syms: Vec<u32> = bytes.iter().map(|&b| b as u32).collect();
        crate::sz::huffman_stage::encode_symbols(&syms)
    }

    fn inverse(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        if bytes.is_empty() {
            return Ok(Vec::new());
        }
        let mut pos = 0;
        let syms = crate::sz::huffman_stage::decode_symbols(bytes, &mut pos)?;
        if pos != bytes.len() {
            return Err(Error::Corrupt("huff stage: trailing bytes".into()));
        }
        syms.iter()
            .map(|&s| {
                u8::try_from(s)
                    .map_err(|_| Error::Corrupt(format!("huff stage: symbol {s} is not a byte")))
            })
            .collect()
    }
}

/// Static range (arithmetic) coder over raw bytes — `codec/arith.rs`
/// promoted to a registry post-stage. Reaches the Shannon bound to
/// within ~0.01 bit/symbol where Huffman pays its up-to-1-bit
/// quantization gap. Empty input passes through.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArithBytes;

impl BytesStage for ArithBytes {
    fn name(&self) -> &'static str {
        "arith"
    }

    fn forward(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        if bytes.is_empty() {
            return Ok(Vec::new());
        }
        let syms: Vec<u32> = bytes.iter().map(|&b| b as u32).collect();
        crate::codec::arith::encode(&syms)
    }

    fn inverse(&self, bytes: &[u8]) -> Result<Vec<u8>> {
        if bytes.is_empty() {
            return Ok(Vec::new());
        }
        let mut pos = 0;
        let syms = crate::codec::arith::decode(bytes, &mut pos)?;
        if pos != bytes.len() {
            return Err(Error::Corrupt("arith stage: trailing bytes".into()));
        }
        syms.iter()
            .map(|&s| {
                u8::try_from(s)
                    .map_err(|_| Error::Corrupt(format!("arith stage: symbol {s} is not a byte")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::atm;
    use crate::testing::Rng;

    #[test]
    fn bitround_respects_allowance_and_quantizes() {
        let f = atm::generate_field_scaled(41, 0, 0);
        let mut data = f.data.clone();
        let allowance = 1e-3 * f.value_range();
        let cfg = BitRound.forward(&mut data, f.dims, allowance).unwrap();
        assert!(cfg.is_empty());
        let q = 2.0 * allowance;
        let mut changed = 0usize;
        for (orig, rounded) in f.data.iter().zip(&data) {
            let err = (*orig as f64 - *rounded as f64).abs();
            assert!(err <= allowance, "{err} > {allowance}");
            // Rounded values sit on the lattice unless the guard fired.
            let lattice = ((*rounded as f64 / q).round() * q) as f32;
            assert!(lattice == *rounded || *rounded == *orig);
            if orig != rounded {
                changed += 1;
            }
        }
        assert!(changed > f.data.len() / 2, "rounding should move most values");
        // Inverse is a no-op that validates its (empty) config.
        let dims = BitRound.inverse(&mut data, f.dims, &[]).unwrap();
        assert_eq!(dims, f.dims);
        assert!(BitRound.inverse(&mut data, f.dims, &[1]).is_err());
    }

    #[test]
    fn bitround_guards_pathological_values() {
        let mut data = vec![f32::MAX, f32::MIN, f32::NAN, f32::INFINITY, 0.0, 1.0];
        let orig = data.clone();
        BitRound.forward(&mut data, Dims::D1(6), 0.25).unwrap();
        // Huge magnitudes and non-finite values pass through unchanged.
        assert_eq!(data[0], orig[0]);
        assert_eq!(data[1], orig[1]);
        assert!(data[2].is_nan());
        assert_eq!(data[3], orig[3]);
        assert_eq!(data[4], 0.0);
        assert_eq!(data[5], 1.0);
        assert!(BitRound.forward(&mut data, Dims::D1(6), 0.0).is_err());
        assert!(BitRound.forward(&mut data, Dims::D1(6), f64::NAN).is_err());
    }

    #[test]
    fn delta_roundtrips_bit_exactly_all_dims() {
        let mut rng = Rng::new(77);
        for dims in [Dims::D1(257), Dims::D2(17, 23), Dims::D3(5, 7, 11)] {
            let mut data: Vec<f32> =
                (0..dims.len()).map(|_| (rng.gauss() * 50.0) as f32).collect();
            // Sprinkle specials: the inverse must restore exact bits.
            data[0] = f32::NAN;
            data[dims.len() / 2] = f32::INFINITY;
            data[dims.len() - 1] = -0.0;
            let orig = data.clone();
            let cfg = DeltaLorenzo.forward(&mut data, dims, 0.0).unwrap();
            assert_ne!(data, orig, "{dims}: transform should change the buffer");
            let back_dims = DeltaLorenzo.inverse(&mut data, Dims::D1(dims.len()), &cfg).unwrap();
            assert_eq!(back_dims, dims);
            let same = orig.iter().zip(&data).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{dims}: inverse not bit-exact");
        }
    }

    #[test]
    fn delta_rejects_bad_config() {
        let mut data = vec![1.0f32; 8];
        let cfg = DeltaLorenzo.forward(&mut data, Dims::D1(8), 0.0).unwrap();
        // Truncated blob, trailing bytes, and mismatched length all err.
        assert!(DeltaLorenzo.inverse(&mut data, Dims::D1(8), &cfg[..cfg.len() - 1]).is_err());
        let mut long = cfg.clone();
        long.push(0);
        assert!(DeltaLorenzo.inverse(&mut data, Dims::D1(8), &long).is_err());
        let mut short = vec![1.0f32; 4];
        assert!(DeltaLorenzo.inverse(&mut short, Dims::D1(4), &cfg).is_err());
        // Forward with inconsistent dims is an argument error.
        assert!(DeltaLorenzo.forward(&mut data, Dims::D1(9), 0.0).is_err());
    }

    #[test]
    fn delta_flattens_smooth_fields() {
        let f = atm::generate_field_scaled(43, 1, 0);
        let mut data = f.data.clone();
        DeltaLorenzo.forward(&mut data, f.dims, 0.0).unwrap();
        // Residual high bytes of a smooth field concentrate near zero:
        // the top residual byte's empirical entropy must be far below 8.
        let mut counts = [0u64; 256];
        for v in &data {
            counts[(v.to_bits() >> 24) as usize] += 1;
        }
        let n = data.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        assert!(h < 6.0, "top residual byte entropy {h} should be well below 8");
    }

    #[test]
    fn shuffle_roundtrips_all_tail_lengths() {
        let mut rng = Rng::new(79);
        for len in [0usize, 1, 2, 3, 4, 5, 31, 4096, 4097, 4099] {
            let data: Vec<u8> = (0..len).map(|_| rng.range(0, 255) as u8).collect();
            let fwd = ShuffleBytes.forward(&data).unwrap();
            assert_eq!(fwd.len(), data.len());
            let back = ShuffleBytes.inverse(&fwd).unwrap();
            assert_eq!(back, data, "len {len}");
        }
        // Spot-check the plane layout: byte 4j+k lands in plane k.
        let data: Vec<u8> = (0..8).collect();
        let fwd = ShuffleBytes.forward(&data).unwrap();
        assert_eq!(fwd, vec![0, 4, 1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn entropy_byte_stages_roundtrip_and_reject_garbage() {
        let mut rng = Rng::new(83);
        // Peaked byte stream, like shuffled smooth-field residuals.
        let data: Vec<u8> =
            (0..20_000).map(|_| if rng.bool(0.9) { 0 } else { rng.range(1, 7) as u8 }).collect();
        for stage in [&HuffBytes as &dyn BytesStage, &ArithBytes] {
            let enc = stage.forward(&data).unwrap();
            assert!(
                enc.len() < data.len() / 2,
                "{}: {} bytes should beat half of {}",
                stage.name(),
                enc.len(),
                data.len()
            );
            assert_eq!(stage.inverse(&enc).unwrap(), data, "{}", stage.name());
            // Empty passthrough.
            assert!(stage.forward(&[]).unwrap().is_empty());
            assert!(stage.inverse(&[]).unwrap().is_empty());
            // Truncation is Corrupt, never a panic.
            for cut in 1..enc.len().min(24) {
                assert!(
                    stage.inverse(&enc[..enc.len() - cut]).is_err(),
                    "{}: truncated by {cut} must err",
                    stage.name()
                );
            }
        }
    }
}
