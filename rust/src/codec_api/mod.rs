//! First-class codec dispatch: the [`Codec`] trait, composable
//! [`Pipeline`]s, and the [`CodecRegistry`].
//!
//! Algorithm 1's output is a compressed byte stream {C_i} plus
//! selection bits {s_i}. Earlier versions hardcoded the selection as a
//! two-variant enum with magic bytes `0`/`1` matched independently in
//! the selector, router, store, and CLI; this module makes the mapping
//! first-class so every backend — SZ, ZFP, the raw passthrough, the
//! blockwise-DCT coder, and composed stage pipelines — is one registry
//! entry behind one interface.
//!
//! Contract (DESIGN.md §4, §15):
//!
//! * Every registry entry is a [`Pipeline`]: zero or more array→array
//!   pre-stages, one array→bytes core codec, zero or more bytes→bytes
//!   post-stages (see [`stage`]). A bare codec is the degenerate
//!   single-stage pipeline and keeps its historical wire format
//!   byte-for-byte.
//! * `Pipeline::id()` is the on-disk selection byte. Ids are unique
//!   within a registry and stable across container versions: 0 = SZ,
//!   1 = ZFP, 2 = raw, 3 = DCT; composed built-ins claim 4+ (see
//!   [`builtin_pipeline_name`]). New entries claim the next free id.
//! * `compress` produces a *bare* pipeline stream (no selection byte);
//!   `decompress` inverts it. SZ and ZFP streams self-describe their
//!   dims; the raw stream intentionally does not (Container v1
//!   compatibility) and decodes as [`Dims::D1`] — the container index
//!   supplies the real dims on the v2 path. Composed streams prepend
//!   one varint-length-prefixed config blob per pre-stage, then the
//!   post-processed core stream.
//! * The registry is the **only** place that maps selection bytes to
//!   pipelines. Container framing (the leading selection byte of a
//!   self-describing payload, the bare-raw quirk of v1 entries) lives
//!   in the registry's encode/decode helpers, nowhere else.

pub mod stage;

use crate::codec::varint;
use crate::data::field::Dims;
use crate::dct::{DctCompressor, DctConfig};
use crate::sz::{SzCompressor, SzConfig};
use crate::zfp::{ZfpCompressor, ZfpConfig};
use crate::{Error, Result};
use stage::{ArithBytes, ArrayStage, BitRound, BytesStage, DeltaLorenzo, HuffBytes, ShuffleBytes};

/// First selection byte claimed by composed built-in pipelines (bare
/// codecs own 0..=3).
pub const FIRST_PIPELINE_ID: u8 = 4;

/// Bit rounding to the error bound, then SZ at the remaining budget.
pub const PIPE_BITROUND_SZ: u8 = 4;
/// Bit rounding, then ZFP at the remaining budget.
pub const PIPE_BITROUND_ZFP: u8 = 5;
/// Bit rounding, SZ, then a byte shuffle over the core stream.
pub const PIPE_BITROUND_SZ_SHUFFLE: u8 = 6;
/// Lossless: Lorenzo residuals → raw bytes → shuffle → Huffman.
pub const PIPE_DELTA_HUFF: u8 = 7;
/// Lossless: Lorenzo residuals → raw bytes → range coder.
pub const PIPE_DELTA_ARITH: u8 = 8;

/// Number of composed-pipeline slots the estimator carries per-field
/// columns for (selection ids `FIRST_PIPELINE_ID ..
/// FIRST_PIPELINE_ID + MAX_COMPOSED`).
pub const MAX_COMPOSED: usize = 8;

/// Name of a composed built-in pipeline (`None` for bare-codec ids and
/// unassigned bytes). Built-in ids are contiguous from
/// [`FIRST_PIPELINE_ID`].
pub const fn builtin_pipeline_name(id: u8) -> Option<&'static str> {
    match id {
        PIPE_BITROUND_SZ => Some("bitround+sz"),
        PIPE_BITROUND_ZFP => Some("bitround+zfp"),
        PIPE_BITROUND_SZ_SHUFFLE => Some("bitround+sz+shuffle"),
        PIPE_DELTA_HUFF => Some("delta+shuffle+huff"),
        PIPE_DELTA_ARITH => Some("delta+arith"),
        _ => None,
    }
}

/// Inverse of [`builtin_pipeline_name`] (case-insensitive).
pub fn builtin_pipeline_id(name: &str) -> Option<u8> {
    let mut id = FIRST_PIPELINE_ID;
    while let Some(n) = builtin_pipeline_name(id) {
        if n.eq_ignore_ascii_case(name) {
            return Some(id);
        }
        id += 1;
    }
    None
}

/// Which registry entry produced (or should produce) a stream — a thin
/// `Copy` wrapper over the registry's stable selection ids, kept as
/// the public selection vocabulary (the paper's s_i bits, generalized).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Choice {
    Sz,
    Zfp,
    /// Uncompressed f32 LE passthrough (the no-compression baseline).
    Raw,
    /// Blockwise-DCT transform coder (the §7 multi-way extension).
    Dct,
    /// A composed stage pipeline, named by its selection id.
    Pipeline(u8),
}

impl Choice {
    /// Every bare-codec choice, in selection-byte order. Composed
    /// pipelines are enumerated by the registry, not here.
    pub const ALL: [Choice; 4] = [Choice::Sz, Choice::Zfp, Choice::Raw, Choice::Dct];

    /// The on-disk selection byte. This is the compatibility shim over
    /// registry ids; the registry entries are the source of truth.
    #[inline]
    pub const fn id(self) -> u8 {
        match self {
            Self::Sz => 0,
            Self::Zfp => 1,
            Self::Raw => 2,
            Self::Dct => 3,
            Self::Pipeline(id) => id,
        }
    }

    /// Inverse of [`Choice::id`] for the built-in registry entries.
    #[inline]
    pub const fn from_id(id: u8) -> Option<Choice> {
        match id {
            0 => Some(Self::Sz),
            1 => Some(Self::Zfp),
            2 => Some(Self::Raw),
            3 => Some(Self::Dct),
            _ => {
                if builtin_pipeline_name(id).is_some() {
                    Some(Self::Pipeline(id))
                } else {
                    None
                }
            }
        }
    }

    pub const fn name(self) -> &'static str {
        match self {
            Self::Sz => "SZ",
            Self::Zfp => "ZFP",
            Self::Raw => "raw",
            Self::Dct => "DCT",
            Self::Pipeline(id) => match builtin_pipeline_name(id) {
                Some(n) => n,
                None => "pipeline",
            },
        }
    }
}

/// An error-bounded compressor behind a uniform interface.
///
/// Implementations must be cheap to construct (the registry is built
/// per call site) and thread-safe (chunk jobs decode concurrently).
pub trait Codec: Send + Sync {
    /// Stable selection byte for this codec.
    fn id(&self) -> u8;

    /// Human-readable name (CLI tables, selection maps).
    fn name(&self) -> &'static str;

    /// True if `decompress(compress(x))` restores `x` bit-exactly for
    /// any bound. Pipelines use this to validate that exactness-
    /// requiring pre-stages (delta) sit above a lossless core.
    fn lossless(&self) -> bool {
        false
    }

    /// Compress `data` (shaped `dims`) under absolute bound `eb_abs`
    /// into a bare codec stream.
    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>>;

    /// Invert [`Codec::compress`].
    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)>;
}

/// SZ (Lorenzo + linear quantization + Huffman) as a registry entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct SzCodec {
    pub cfg: SzConfig,
}

impl Codec for SzCodec {
    fn id(&self) -> u8 {
        Choice::Sz.id()
    }

    fn name(&self) -> &'static str {
        Choice::Sz.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        SzCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        SzCompressor::new(self.cfg).decompress(stream)
    }
}

/// ZFP (blockwise orthogonal transform + embedded coding) as a
/// registry entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ZfpCodec {
    pub cfg: ZfpConfig,
}

impl Codec for ZfpCodec {
    fn id(&self) -> u8 {
        Choice::Zfp.id()
    }

    fn name(&self) -> &'static str {
        Choice::Zfp.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        ZfpCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        ZfpCompressor::new(self.cfg).decompress(stream)
    }
}

/// Lossless f32 LE passthrough. The stream is the bytes themselves —
/// no dims header, for bit-compatibility with Container v1's raw
/// entries — so `decompress` reports `Dims::D1`; container indexes
/// carry the real shape.
#[derive(Clone, Copy, Debug, Default)]
pub struct RawCodec;

impl Codec for RawCodec {
    fn id(&self) -> u8 {
        Choice::Raw.id()
    }

    fn name(&self) -> &'static str {
        Choice::Raw.name()
    }

    fn lossless(&self) -> bool {
        true
    }

    fn compress(&self, data: &[f32], dims: Dims, _eb_abs: f64) -> Result<Vec<u8>> {
        debug_assert_eq!(dims.len(), data.len());
        let mut out = Vec::with_capacity(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        Ok(out)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        if stream.len() % 4 != 0 {
            return Err(Error::Corrupt(format!(
                "raw stream of {} bytes is not a multiple of 4",
                stream.len()
            )));
        }
        let data: Vec<f32> = stream
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let dims = Dims::D1(data.len());
        Ok((data, dims))
    }
}

/// SSEM-style blockwise DCT (orthogonal transform + static coefficient
/// quantization + Huffman) as a registry entry — selection byte 3.
#[derive(Clone, Copy, Debug, Default)]
pub struct DctCodec {
    pub cfg: DctConfig,
}

impl Codec for DctCodec {
    fn id(&self) -> u8 {
        Choice::Dct.id()
    }

    fn name(&self) -> &'static str {
        Choice::Dct.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        DctCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        DctCompressor::new(self.cfg).decompress(stream)
    }
}

/// An ordered stage chain behind one selection byte: pre-stages →
/// core codec → post-stages (DESIGN.md §15).
///
/// Wire format of a composed stream: one varint-length-prefixed config
/// blob per pre-stage (declared order), then the core stream passed
/// through the post-stages in order. A bare codec wrapped by
/// [`Pipeline::single`] has zero stages and zero header bytes, so its
/// stream is byte-identical to the historical flat-registry output —
/// the compatibility invariant the differential tests pin.
///
/// Error-budget split: the absolute bound is divided evenly across the
/// lossy participants (lossy pre-stages plus a lossy core), so the
/// triangle inequality keeps the end-to-end pointwise error within
/// `eb_abs`.
pub struct Pipeline {
    id: u8,
    name: &'static str,
    pre: Vec<Box<dyn ArrayStage>>,
    core: Box<dyn Codec>,
    post: Vec<Box<dyn BytesStage>>,
}

impl Pipeline {
    /// Wrap a bare codec as the degenerate single-stage pipeline.
    pub fn single(core: Box<dyn Codec>) -> Pipeline {
        Pipeline { id: core.id(), name: core.name(), pre: Vec::new(), core, post: Vec::new() }
    }

    /// Build a composed pipeline. Rejects chains where a stage that
    /// requires bit-exact downstream reconstruction (the delta
    /// transform) is followed by any lossy stage or a lossy core.
    pub fn composed(
        id: u8,
        name: &'static str,
        pre: Vec<Box<dyn ArrayStage>>,
        core: Box<dyn Codec>,
        post: Vec<Box<dyn BytesStage>>,
    ) -> Result<Pipeline> {
        if let Some(i) = pre.iter().position(|s| s.requires_exact_downstream()) {
            let later_lossless = pre[i + 1..].iter().all(|s| s.lossless());
            if !later_lossless || !core.lossless() {
                return Err(Error::InvalidArg(format!(
                    "pipeline '{name}': stage '{}' requires bit-exact downstream \
                     reconstruction, so every later pre-stage and the core codec \
                     must be lossless",
                    pre[i].name()
                )));
            }
        }
        Ok(Pipeline { id, name, pre, core, post })
    }

    /// Selection byte of this entry.
    pub fn id(&self) -> u8 {
        self.id
    }

    /// Display name (bare codec name or composed pipeline spec).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// True for a bare codec with no pre/post stages.
    pub fn is_single(&self) -> bool {
        self.pre.is_empty() && self.post.is_empty()
    }

    /// True if the whole chain restores input bits exactly.
    pub fn lossless(&self) -> bool {
        self.core.lossless() && self.pre.iter().all(|s| s.lossless())
    }

    /// Compress under absolute bound `eb_abs` into a bare pipeline
    /// stream (no selection byte).
    pub fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        if self.is_single() {
            return self.core.compress(data, dims, eb_abs);
        }
        let lossy = self.pre.iter().filter(|s| !s.lossless()).count()
            + usize::from(!self.core.lossless());
        let allowance = if lossy > 0 { eb_abs / lossy as f64 } else { 0.0 };
        let mut buf = data.to_vec();
        let mut out = Vec::new();
        for s in &self.pre {
            let a = if s.lossless() { 0.0 } else { allowance };
            let cfg = s.forward(&mut buf, dims, a)?;
            varint::write_bytes(&mut out, &cfg);
        }
        let eb_core = if self.core.lossless() { eb_abs } else { allowance };
        let mut bytes = self.core.compress(&buf, dims, eb_core)?;
        for p in &self.post {
            bytes = p.forward(&bytes)?;
        }
        out.extend_from_slice(&bytes);
        Ok(out)
    }

    /// Invert [`Pipeline::compress`]. Truncated or malformed stage
    /// config blobs decode as `Corrupt`, never a panic.
    pub fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        if self.is_single() {
            return self.core.decompress(stream);
        }
        let mut pos = 0;
        let mut cfgs: Vec<&[u8]> = Vec::with_capacity(self.pre.len());
        for _ in &self.pre {
            cfgs.push(varint::read_bytes(stream, &mut pos)?);
        }
        let mut bytes = stream[pos..].to_vec();
        for p in self.post.iter().rev() {
            bytes = p.inverse(&bytes)?;
        }
        let (mut data, mut dims) = self.core.decompress(&bytes)?;
        for (s, cfg) in self.pre.iter().zip(cfgs.iter()).rev() {
            dims = s.inverse(&mut data, dims, cfg)?;
        }
        Ok((data, dims))
    }
}

/// The composed built-in pipelines registered by
/// [`CodecRegistry::standard`], ids [`FIRST_PIPELINE_ID`]..
fn builtin_pipelines(sz: SzConfig, zfp: ZfpConfig) -> Vec<Pipeline> {
    let p = |id, pre, core, post| {
        let name = builtin_pipeline_name(id).expect("builtin id has a name");
        Pipeline::composed(id, name, pre, core, post).expect("builtin pipeline is valid")
    };
    vec![
        p(
            PIPE_BITROUND_SZ,
            vec![Box::new(BitRound) as Box<dyn ArrayStage>],
            Box::new(SzCodec { cfg: sz }) as Box<dyn Codec>,
            vec![],
        ),
        p(
            PIPE_BITROUND_ZFP,
            vec![Box::new(BitRound) as Box<dyn ArrayStage>],
            Box::new(ZfpCodec { cfg: zfp }),
            vec![],
        ),
        p(
            PIPE_BITROUND_SZ_SHUFFLE,
            vec![Box::new(BitRound) as Box<dyn ArrayStage>],
            Box::new(SzCodec { cfg: sz }),
            vec![Box::new(ShuffleBytes) as Box<dyn BytesStage>],
        ),
        p(
            PIPE_DELTA_HUFF,
            vec![Box::new(DeltaLorenzo) as Box<dyn ArrayStage>],
            Box::new(RawCodec),
            vec![Box::new(ShuffleBytes) as Box<dyn BytesStage>, Box::new(HuffBytes)],
        ),
        p(
            PIPE_DELTA_ARITH,
            vec![Box::new(DeltaLorenzo) as Box<dyn ArrayStage>],
            Box::new(RawCodec),
            vec![Box::new(ArithBytes) as Box<dyn BytesStage>],
        ),
    ]
}

/// Resolves selection bytes to pipelines — the single source of truth
/// for the {s_i} → entry mapping (DESIGN.md §11, §15). Every container
/// chunk records the selection byte of the entry that wrote it; readers
/// hand that byte back to the registry to decode, which is why new
/// pipelines extend the wire format without changing it.
pub struct CodecRegistry {
    pipelines: Vec<Pipeline>,
}

impl std::fmt::Debug for CodecRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let entries: Vec<String> =
            self.pipelines.iter().map(|p| format!("{}={}", p.id(), p.name())).collect();
        f.debug_struct("CodecRegistry").field("pipelines", &entries).finish()
    }
}

impl Default for CodecRegistry {
    fn default() -> Self {
        CodecRegistry::standard(SzConfig::default(), ZfpConfig::default(), DctConfig::default())
    }
}

impl CodecRegistry {
    /// An empty registry (for custom codec sets).
    pub fn empty() -> Self {
        CodecRegistry { pipelines: Vec::new() }
    }

    /// The standard registry: SZ, ZFP, the raw passthrough, DCT, and
    /// the composed built-in pipelines.
    pub fn standard(sz: SzConfig, zfp: ZfpConfig, dct: DctConfig) -> Self {
        let mut r = CodecRegistry::empty();
        r.register(Box::new(SzCodec { cfg: sz })).expect("fresh registry");
        r.register(Box::new(ZfpCodec { cfg: zfp })).expect("fresh registry");
        r.register(Box::new(RawCodec)).expect("fresh registry");
        r.register(Box::new(DctCodec { cfg: dct })).expect("fresh registry");
        for p in builtin_pipelines(sz, zfp) {
            r.register_pipeline(p).expect("fresh registry");
        }
        r
    }

    /// Add a bare codec as a single-stage pipeline; rejects duplicate
    /// selection ids.
    pub fn register(&mut self, codec: Box<dyn Codec>) -> Result<()> {
        self.register_pipeline(Pipeline::single(codec))
    }

    /// Add a pipeline; rejects duplicate selection ids.
    pub fn register_pipeline(&mut self, pipeline: Pipeline) -> Result<()> {
        if self.lookup(pipeline.id()).is_some() {
            return Err(Error::InvalidArg(format!(
                "registry id {} ('{}') already registered",
                pipeline.id(),
                pipeline.name()
            )));
        }
        self.pipelines.push(pipeline);
        Ok(())
    }

    /// Pipeline for a selection byte, if registered.
    pub fn lookup(&self, id: u8) -> Option<&Pipeline> {
        self.pipelines.iter().find(|p| p.id() == id)
    }

    /// Pipeline for a selection byte, or a corruption error.
    pub fn get(&self, id: u8) -> Result<&Pipeline> {
        self.lookup(id)
            .ok_or_else(|| Error::Corrupt(format!("bad selection bit {id}")))
    }

    /// Pipeline by name (case-insensitive).
    pub fn by_name(&self, name: &str) -> Option<&Pipeline> {
        self.pipelines.iter().find(|p| p.name().eq_ignore_ascii_case(name))
    }

    /// Display name for a selection byte ("?" when unregistered).
    pub fn name_of(&self, id: u8) -> &'static str {
        self.lookup(id).map(|p| p.name()).unwrap_or("?")
    }

    /// Registered (id, name) pairs, in registration order.
    pub fn entries(&self) -> impl Iterator<Item = (u8, &'static str)> + '_ {
        self.pipelines.iter().map(|p| (p.id(), p.name()))
    }

    /// Compress into a self-describing container payload: one leading
    /// selection byte, then the bare pipeline stream.
    pub fn encode(&self, choice: Choice, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        let pipeline = self.get(choice.id())?;
        let stream = pipeline.compress(data, dims, eb_abs)?;
        let mut out = Vec::with_capacity(stream.len() + 1);
        out.push(pipeline.id());
        out.extend_from_slice(&stream);
        Ok(out)
    }

    /// Decode a self-describing container payload (leading selection
    /// byte + bare stream).
    pub fn decode(&self, container: &[u8]) -> Result<(Vec<f32>, Dims)> {
        let (sel, stream) = split_container(container)?;
        self.decode_stream(sel, stream)
    }

    /// Decode a bare pipeline stream under an explicit selection byte.
    pub fn decode_stream(&self, selection: u8, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        self.get(selection)?.decompress(stream)
    }

    /// Decode a Container v1 entry. Compressed v1 entries carry the
    /// selection byte inline at the head of the payload; raw entries
    /// (selection = 2) are bare f32 LE bytes. This is the only place
    /// that knows the v1 framing quirk.
    pub fn decode_v1_entry(&self, selection: u8, payload: &[u8]) -> Result<(Vec<f32>, Dims)> {
        if selection == Choice::Raw.id() {
            return self.decode_stream(selection, payload);
        }
        let (inline, stream) = split_container(payload)?;
        if inline != selection {
            return Err(Error::Corrupt(format!(
                "entry selection {selection} disagrees with payload selection {inline}"
            )));
        }
        self.decode_stream(selection, stream)
    }
}

/// Split a self-describing container payload into its selection byte
/// and bare stream.
pub fn split_container(payload: &[u8]) -> Result<(u8, &[u8])> {
    match payload.split_first() {
        Some((sel, stream)) => Ok((*sel, stream)),
        None => Err(Error::Corrupt("empty container".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::atm;

    fn registry() -> CodecRegistry {
        CodecRegistry::default()
    }

    /// Every registered choice: the bare codecs plus the composed
    /// built-in pipelines.
    fn all_choices() -> Vec<Choice> {
        let mut v = Choice::ALL.to_vec();
        let mut id = FIRST_PIPELINE_ID;
        while builtin_pipeline_name(id).is_some() {
            v.push(Choice::Pipeline(id));
            id += 1;
        }
        v
    }

    #[test]
    fn choice_ids_roundtrip() {
        for c in all_choices() {
            assert_eq!(Choice::from_id(c.id()), Some(c));
        }
        assert_eq!(Choice::Dct.id(), 3);
        assert_eq!(Choice::from_id(PIPE_DELTA_HUFF), Some(Choice::Pipeline(PIPE_DELTA_HUFF)));
        assert_eq!(Choice::from_id(42), None);
        assert_eq!(Choice::Pipeline(PIPE_BITROUND_SZ).name(), "bitround+sz");
        assert_eq!(builtin_pipeline_id("bitround+sz"), Some(PIPE_BITROUND_SZ));
        assert_eq!(builtin_pipeline_id("BitRound+SZ"), Some(PIPE_BITROUND_SZ));
        assert_eq!(builtin_pipeline_id("zstd"), None);
    }

    #[test]
    fn registry_resolves_all_standard_ids() {
        let r = registry();
        for c in all_choices() {
            let p = r.get(c.id()).unwrap();
            assert_eq!(p.id(), c.id());
            assert_eq!(p.name(), c.name());
        }
        assert!(r.get(42).is_err());
        assert_eq!(r.name_of(42), "?");
        assert!(r.by_name("sz").is_some());
        assert!(r.by_name("dct").is_some());
        assert!(r.by_name("bitround+sz+shuffle").is_some());
        assert!(r.by_name("zstd").is_none());
        assert_eq!(r.entries().count(), 9);
    }

    #[test]
    fn duplicate_id_rejected() {
        let mut r = registry();
        assert!(r.register(Box::new(RawCodec)).is_err());
    }

    #[test]
    fn every_entry_roundtrips_through_encode_decode() {
        let r = registry();
        let f = atm::generate_field_scaled(31, 0, 0);
        let vr = f.value_range();
        let eb = 1e-3 * vr;
        for choice in all_choices() {
            let payload = r.encode(choice, &f.data, f.dims, eb).unwrap();
            assert_eq!(payload[0], choice.id());
            let (data, dims) = r.decode(&payload).unwrap();
            assert_eq!(data.len(), f.data.len(), "{choice:?}");
            if choice != Choice::Raw {
                assert_eq!(dims, f.dims, "{choice:?}");
            }
            let worst = f
                .data
                .iter()
                .zip(&data)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0f64, f64::max);
            assert!(worst <= eb * (1.0 + 1e-6), "{choice:?}: {worst} > {eb}");
            if r.get(choice.id()).unwrap().lossless() {
                assert_eq!(data, f.data, "{choice:?}: lossless pipeline must be exact");
            }
        }
    }

    #[test]
    fn single_stage_pipelines_are_byte_identical_to_bare_codecs() {
        // The compatibility invariant: wrapping a codec as a pipeline
        // adds zero header bytes, so historical containers stay
        // readable and new ones stay byte-identical.
        let r = registry();
        let f = atm::generate_field_scaled(29, 2, 0);
        let eb = 1e-3 * f.value_range();
        let direct: Vec<(Choice, Vec<u8>)> = vec![
            (Choice::Sz, SzCompressor::new(SzConfig::default()).compress(&f.data, f.dims, eb).unwrap()),
            (Choice::Zfp, ZfpCompressor::new(ZfpConfig::default()).compress(&f.data, f.dims, eb).unwrap()),
            (Choice::Raw, RawCodec.compress(&f.data, f.dims, eb).unwrap()),
            (Choice::Dct, DctCompressor::new(DctConfig::default()).compress(&f.data, f.dims, eb).unwrap()),
        ];
        for (choice, bare) in direct {
            let via_pipeline = r.get(choice.id()).unwrap().compress(&f.data, f.dims, eb).unwrap();
            assert_eq!(via_pipeline, bare, "{choice:?}");
        }
    }

    #[test]
    fn composed_pipeline_splits_budget_and_stays_bounded() {
        let r = registry();
        let f = atm::generate_field_scaled(47, 7, 0);
        let eb = 1e-4 * f.value_range();
        let p = r.get(PIPE_BITROUND_SZ).unwrap();
        let stream = p.compress(&f.data, f.dims, eb).unwrap();
        let (data, dims) = p.decompress(&stream).unwrap();
        assert_eq!(dims, f.dims);
        let worst = f
            .data
            .iter()
            .zip(&data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        assert!(worst <= eb * (1.0 + 1e-6), "{worst} > {eb}");
        // The composed stream differs from plain SZ at the same bound
        // (the bitround stage consumed half the budget).
        let plain = r.get(Choice::Sz.id()).unwrap().compress(&f.data, f.dims, eb).unwrap();
        assert_ne!(stream, plain);
    }

    #[test]
    fn composed_stream_corruption_is_an_error_not_a_panic() {
        let r = registry();
        let f = atm::generate_field_scaled(53, 3, 0);
        let eb = 1e-3 * f.value_range();
        for id in [PIPE_BITROUND_SZ, PIPE_BITROUND_SZ_SHUFFLE, PIPE_DELTA_HUFF, PIPE_DELTA_ARITH] {
            let p = r.get(id).unwrap();
            let stream = p.compress(&f.data, f.dims, eb).unwrap();
            assert!(p.decompress(&stream).is_ok());
            // Every strict prefix must fail cleanly.
            for cut in [0usize, 1, 2, stream.len() / 2, stream.len() - 1] {
                assert!(p.decompress(&stream[..cut]).is_err(), "pipeline {id} prefix {cut}");
            }
        }
    }

    #[test]
    fn exactness_validation_rejects_lossy_core_under_delta() {
        let bad = Pipeline::composed(
            99,
            "delta+sz",
            vec![Box::new(DeltaLorenzo) as Box<dyn ArrayStage>],
            Box::new(SzCodec::default()),
            vec![],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn raw_codec_is_exact_and_bare() {
        let r = registry();
        let data = [1.5f32, -2.25, 0.0, 3.75];
        let stream =
            r.get(Choice::Raw.id()).unwrap().compress(&data, Dims::D1(4), 0.0).unwrap();
        assert_eq!(stream.len(), 16);
        let (back, dims) = r.decode_stream(Choice::Raw.id(), &stream).unwrap();
        assert_eq!(back, data);
        assert_eq!(dims, Dims::D1(4));
        assert!(r.decode_stream(Choice::Raw.id(), &stream[..7]).is_err());
    }

    #[test]
    fn v1_entry_framing() {
        let r = registry();
        let f = atm::generate_field_scaled(37, 1, 0);
        let eb = 1e-3 * f.value_range();
        // Compressed entry: selection byte inline.
        let payload = r.encode(Choice::Zfp, &f.data, f.dims, eb).unwrap();
        let (data, dims) = r.decode_v1_entry(Choice::Zfp.id(), &payload).unwrap();
        assert_eq!(dims, f.dims);
        assert_eq!(data.len(), f.data.len());
        // Mismatched selection is corruption.
        assert!(r.decode_v1_entry(Choice::Sz.id(), &payload).is_err());
        // Raw entry: bare bytes, no inline selection byte.
        let raw: Vec<u8> = f.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let (data, _) = r.decode_v1_entry(Choice::Raw.id(), &raw).unwrap();
        assert_eq!(data, f.data);
        // Empty payload of a compressed entry is corruption, not panic.
        assert!(r.decode_v1_entry(Choice::Sz.id(), &[]).is_err());
    }
}
