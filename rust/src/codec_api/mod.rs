//! First-class codec dispatch: the [`Codec`] trait and the
//! [`CodecRegistry`].
//!
//! Algorithm 1's output is a compressed byte stream {C_i} plus
//! selection bits {s_i}. Earlier versions hardcoded the selection as a
//! two-variant enum with magic bytes `0`/`1` matched independently in
//! the selector, router, store, and CLI; this module makes the mapping
//! first-class so every backend — SZ, ZFP, the raw passthrough, and
//! the blockwise-DCT coder — is one registry entry behind one
//! interface.
//!
//! Contract (DESIGN.md §4):
//!
//! * `id()` is the on-disk selection byte. Ids are unique within a
//!   registry and stable across container versions: 0 = SZ, 1 = ZFP,
//!   2 = raw, 3 = DCT. New codecs claim the next free id.
//! * `compress` produces a *bare* codec stream (no selection byte);
//!   `decompress` inverts it. SZ and ZFP streams self-describe their
//!   dims; the raw stream intentionally does not (Container v1
//!   compatibility) and decodes as [`Dims::D1`] — the container index
//!   supplies the real dims on the v2 path.
//! * The registry is the **only** place that maps selection bytes to
//!   codecs. Container framing (the leading selection byte of a
//!   self-describing payload, the bare-raw quirk of v1 entries) lives
//!   in the registry's encode/decode helpers, nowhere else.

use crate::data::field::Dims;
use crate::dct::{DctCompressor, DctConfig};
use crate::sz::{SzCompressor, SzConfig};
use crate::zfp::{ZfpCompressor, ZfpConfig};
use crate::{Error, Result};

/// Which codec produced (or should produce) a stream — a thin `Copy`
/// wrapper over the registry's stable codec ids, kept as the public
/// selection vocabulary (the paper's s_i bits, generalized).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Choice {
    Sz,
    Zfp,
    /// Uncompressed f32 LE passthrough (the no-compression baseline).
    Raw,
    /// Blockwise-DCT transform coder (the §7 multi-way extension).
    Dct,
}

impl Choice {
    /// Every registered choice, in selection-byte order.
    pub const ALL: [Choice; 4] = [Choice::Sz, Choice::Zfp, Choice::Raw, Choice::Dct];

    /// The on-disk selection byte. This is the compatibility shim over
    /// codec ids; the registry entries are the source of truth.
    #[inline]
    pub const fn id(self) -> u8 {
        match self {
            Self::Sz => 0,
            Self::Zfp => 1,
            Self::Raw => 2,
            Self::Dct => 3,
        }
    }

    /// Inverse of [`Choice::id`] for the built-in codecs.
    #[inline]
    pub const fn from_id(id: u8) -> Option<Choice> {
        match id {
            0 => Some(Self::Sz),
            1 => Some(Self::Zfp),
            2 => Some(Self::Raw),
            3 => Some(Self::Dct),
            _ => None,
        }
    }

    pub const fn name(self) -> &'static str {
        match self {
            Self::Sz => "SZ",
            Self::Zfp => "ZFP",
            Self::Raw => "raw",
            Self::Dct => "DCT",
        }
    }
}

/// An error-bounded compressor behind a uniform interface.
///
/// Implementations must be cheap to construct (the registry is built
/// per call site) and thread-safe (chunk jobs decode concurrently).
pub trait Codec: Send + Sync {
    /// Stable selection byte for this codec.
    fn id(&self) -> u8;

    /// Human-readable name (CLI tables, selection maps).
    fn name(&self) -> &'static str;

    /// Compress `data` (shaped `dims`) under absolute bound `eb_abs`
    /// into a bare codec stream.
    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>>;

    /// Invert [`Codec::compress`].
    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)>;
}

/// SZ (Lorenzo + linear quantization + Huffman) as a registry entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct SzCodec {
    pub cfg: SzConfig,
}

impl Codec for SzCodec {
    fn id(&self) -> u8 {
        Choice::Sz.id()
    }

    fn name(&self) -> &'static str {
        Choice::Sz.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        SzCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        SzCompressor::new(self.cfg).decompress(stream)
    }
}

/// ZFP (blockwise orthogonal transform + embedded coding) as a
/// registry entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ZfpCodec {
    pub cfg: ZfpConfig,
}

impl Codec for ZfpCodec {
    fn id(&self) -> u8 {
        Choice::Zfp.id()
    }

    fn name(&self) -> &'static str {
        Choice::Zfp.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        ZfpCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        ZfpCompressor::new(self.cfg).decompress(stream)
    }
}

/// Lossless f32 LE passthrough. The stream is the bytes themselves —
/// no dims header, for bit-compatibility with Container v1's raw
/// entries — so `decompress` reports `Dims::D1`; container indexes
/// carry the real shape.
#[derive(Clone, Copy, Debug, Default)]
pub struct RawCodec;

impl Codec for RawCodec {
    fn id(&self) -> u8 {
        Choice::Raw.id()
    }

    fn name(&self) -> &'static str {
        Choice::Raw.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, _eb_abs: f64) -> Result<Vec<u8>> {
        debug_assert_eq!(dims.len(), data.len());
        let mut out = Vec::with_capacity(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        Ok(out)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        if stream.len() % 4 != 0 {
            return Err(Error::Corrupt(format!(
                "raw stream of {} bytes is not a multiple of 4",
                stream.len()
            )));
        }
        let data: Vec<f32> = stream
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let dims = Dims::D1(data.len());
        Ok((data, dims))
    }
}

/// SSEM-style blockwise DCT (orthogonal transform + static coefficient
/// quantization + Huffman) as a registry entry — selection byte 3.
#[derive(Clone, Copy, Debug, Default)]
pub struct DctCodec {
    pub cfg: DctConfig,
}

impl Codec for DctCodec {
    fn id(&self) -> u8 {
        Choice::Dct.id()
    }

    fn name(&self) -> &'static str {
        Choice::Dct.name()
    }

    fn compress(&self, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        DctCompressor::new(self.cfg).compress(data, dims, eb_abs)
    }

    fn decompress(&self, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        DctCompressor::new(self.cfg).decompress(stream)
    }
}

/// Resolves selection bytes to codecs — the single source of truth for
/// the {s_i} → codec mapping (DESIGN.md §11). Every container chunk
/// records the selection byte of the codec that wrote it; readers hand
/// that byte back to the registry to decode, which is why new codecs
/// extend the wire format without changing it.
pub struct CodecRegistry {
    codecs: Vec<Box<dyn Codec>>,
}

impl std::fmt::Debug for CodecRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let entries: Vec<String> =
            self.codecs.iter().map(|c| format!("{}={}", c.id(), c.name())).collect();
        f.debug_struct("CodecRegistry").field("codecs", &entries).finish()
    }
}

impl Default for CodecRegistry {
    fn default() -> Self {
        CodecRegistry::standard(SzConfig::default(), ZfpConfig::default(), DctConfig::default())
    }
}

impl CodecRegistry {
    /// An empty registry (for custom codec sets).
    pub fn empty() -> Self {
        CodecRegistry { codecs: Vec::new() }
    }

    /// The standard registry: SZ, ZFP, the raw passthrough, and DCT.
    pub fn standard(sz: SzConfig, zfp: ZfpConfig, dct: DctConfig) -> Self {
        let mut r = CodecRegistry::empty();
        r.register(Box::new(SzCodec { cfg: sz })).expect("fresh registry");
        r.register(Box::new(ZfpCodec { cfg: zfp })).expect("fresh registry");
        r.register(Box::new(RawCodec)).expect("fresh registry");
        r.register(Box::new(DctCodec { cfg: dct })).expect("fresh registry");
        r
    }

    /// Add a codec; rejects duplicate selection ids.
    pub fn register(&mut self, codec: Box<dyn Codec>) -> Result<()> {
        if self.lookup(codec.id()).is_some() {
            return Err(Error::InvalidArg(format!(
                "codec id {} ('{}') already registered",
                codec.id(),
                codec.name()
            )));
        }
        self.codecs.push(codec);
        Ok(())
    }

    /// Codec for a selection byte, if registered.
    pub fn lookup(&self, id: u8) -> Option<&dyn Codec> {
        self.codecs.iter().find(|c| c.id() == id).map(|c| c.as_ref())
    }

    /// Codec for a selection byte, or a corruption error.
    pub fn get(&self, id: u8) -> Result<&dyn Codec> {
        self.lookup(id)
            .ok_or_else(|| Error::Corrupt(format!("bad selection bit {id}")))
    }

    /// Codec by name (case-insensitive).
    pub fn by_name(&self, name: &str) -> Option<&dyn Codec> {
        self.codecs
            .iter()
            .find(|c| c.name().eq_ignore_ascii_case(name))
            .map(|c| c.as_ref())
    }

    /// Display name for a selection byte ("?" when unregistered).
    pub fn name_of(&self, id: u8) -> &'static str {
        self.lookup(id).map(|c| c.name()).unwrap_or("?")
    }

    /// Registered (id, name) pairs, in registration order.
    pub fn entries(&self) -> impl Iterator<Item = (u8, &'static str)> + '_ {
        self.codecs.iter().map(|c| (c.id(), c.name()))
    }

    /// Compress into a self-describing container payload: one leading
    /// selection byte, then the bare codec stream.
    pub fn encode(&self, choice: Choice, data: &[f32], dims: Dims, eb_abs: f64) -> Result<Vec<u8>> {
        let codec = self.get(choice.id())?;
        let stream = codec.compress(data, dims, eb_abs)?;
        let mut out = Vec::with_capacity(stream.len() + 1);
        out.push(codec.id());
        out.extend_from_slice(&stream);
        Ok(out)
    }

    /// Decode a self-describing container payload (leading selection
    /// byte + bare stream).
    pub fn decode(&self, container: &[u8]) -> Result<(Vec<f32>, Dims)> {
        let (sel, stream) = split_container(container)?;
        self.decode_stream(sel, stream)
    }

    /// Decode a bare codec stream under an explicit selection byte.
    pub fn decode_stream(&self, selection: u8, stream: &[u8]) -> Result<(Vec<f32>, Dims)> {
        self.get(selection)?.decompress(stream)
    }

    /// Decode a Container v1 entry. Compressed v1 entries carry the
    /// selection byte inline at the head of the payload; raw entries
    /// (selection = 2) are bare f32 LE bytes. This is the only place
    /// that knows the v1 framing quirk.
    pub fn decode_v1_entry(&self, selection: u8, payload: &[u8]) -> Result<(Vec<f32>, Dims)> {
        if selection == Choice::Raw.id() {
            return self.decode_stream(selection, payload);
        }
        let (inline, stream) = split_container(payload)?;
        if inline != selection {
            return Err(Error::Corrupt(format!(
                "entry selection {selection} disagrees with payload selection {inline}"
            )));
        }
        self.decode_stream(selection, stream)
    }
}

/// Split a self-describing container payload into its selection byte
/// and bare stream.
pub fn split_container(payload: &[u8]) -> Result<(u8, &[u8])> {
    match payload.split_first() {
        Some((sel, stream)) => Ok((*sel, stream)),
        None => Err(Error::Corrupt("empty container".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::atm;

    fn registry() -> CodecRegistry {
        CodecRegistry::default()
    }

    #[test]
    fn choice_ids_roundtrip() {
        for c in Choice::ALL {
            assert_eq!(Choice::from_id(c.id()), Some(c));
        }
        assert_eq!(Choice::Dct.id(), 3);
        assert_eq!(Choice::from_id(7), None);
    }

    #[test]
    fn registry_resolves_all_standard_ids() {
        let r = registry();
        for c in Choice::ALL {
            let codec = r.get(c.id()).unwrap();
            assert_eq!(codec.id(), c.id());
            assert_eq!(codec.name(), c.name());
        }
        assert!(r.get(9).is_err());
        assert_eq!(r.name_of(9), "?");
        assert!(r.by_name("sz").is_some());
        assert!(r.by_name("dct").is_some());
        assert!(r.by_name("zstd").is_none());
        assert_eq!(r.entries().count(), 4);
    }

    #[test]
    fn duplicate_id_rejected() {
        let mut r = registry();
        assert!(r.register(Box::new(RawCodec)).is_err());
    }

    #[test]
    fn every_codec_roundtrips_through_encode_decode() {
        let r = registry();
        let f = atm::generate_field_scaled(31, 0, 0);
        let vr = f.value_range();
        let eb = 1e-3 * vr;
        for choice in Choice::ALL {
            let payload = r.encode(choice, &f.data, f.dims, eb).unwrap();
            assert_eq!(payload[0], choice.id());
            let (data, dims) = r.decode(&payload).unwrap();
            assert_eq!(data.len(), f.data.len(), "{choice:?}");
            if choice != Choice::Raw {
                assert_eq!(dims, f.dims, "{choice:?}");
            }
            let worst = f
                .data
                .iter()
                .zip(&data)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0f64, f64::max);
            assert!(worst <= eb * (1.0 + 1e-6), "{choice:?}: {worst} > {eb}");
        }
    }

    #[test]
    fn raw_codec_is_exact_and_bare() {
        let r = registry();
        let data = [1.5f32, -2.25, 0.0, 3.75];
        let stream =
            r.get(Choice::Raw.id()).unwrap().compress(&data, Dims::D1(4), 0.0).unwrap();
        assert_eq!(stream.len(), 16);
        let (back, dims) = r.decode_stream(Choice::Raw.id(), &stream).unwrap();
        assert_eq!(back, data);
        assert_eq!(dims, Dims::D1(4));
        assert!(r.decode_stream(Choice::Raw.id(), &stream[..7]).is_err());
    }

    #[test]
    fn v1_entry_framing() {
        let r = registry();
        let f = atm::generate_field_scaled(37, 1, 0);
        let eb = 1e-3 * f.value_range();
        // Compressed entry: selection byte inline.
        let payload = r.encode(Choice::Zfp, &f.data, f.dims, eb).unwrap();
        let (data, dims) = r.decode_v1_entry(Choice::Zfp.id(), &payload).unwrap();
        assert_eq!(dims, f.dims);
        assert_eq!(data.len(), f.data.len());
        // Mismatched selection is corruption.
        assert!(r.decode_v1_entry(Choice::Sz.id(), &payload).is_err());
        // Raw entry: bare bytes, no inline selection byte.
        let raw: Vec<u8> = f.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let (data, _) = r.decode_v1_entry(Choice::Raw.id(), &raw).unwrap();
        assert_eq!(data, f.data);
        // Empty payload of a compressed entry is corruption, not panic.
        assert!(r.decode_v1_entry(Choice::Sz.id(), &[]).is_err());
    }
}
