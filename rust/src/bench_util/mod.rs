//! Manual benchmark harness (criterion is unavailable offline — see
//! DESIGN.md §9): warmup + timed iterations with mean/σ, plus plain-
//! text table/series printers shared by all `cargo bench` targets so
//! every paper table and figure prints in a uniform format that
//! EXPERIMENTS.md records verbatim.

use std::time::{Duration, Instant};

/// Timing statistics for one benchmark case.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub mean: Duration,
    pub std_dev: Duration,
    pub iters: u32,
}

impl Timing {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:>10.3} ms ± {:>7.3} ms (n={})",
            self.mean.as_secs_f64() * 1e3,
            self.std_dev.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// CI smoke knob: `ADAPTIVEC_BENCH_ITERS` caps measured iterations so
/// a bench target can run in seconds on a runner while keeping its
/// full default locally.
pub fn iters_override(default: u32) -> u32 {
    env_parse("ADAPTIVEC_BENCH_ITERS", default).max(1)
}

/// CI smoke knob: `ADAPTIVEC_BENCH_SCALE` overrides a bench's dataset
/// scale level (0 = smallest).
pub fn scale_override(default: u8) -> u8 {
    env_parse("ADAPTIVEC_BENCH_SCALE", default)
}

fn env_parse<T: std::str::FromStr>(var: &str, default: T) -> T {
    std::env::var(var).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Collects named timings and writes them as a JSON array — the
/// machine-readable artifact the CI `bench-smoke` job uploads so the
/// perf trajectory is diffable across commits. Hand-rolled (no serde;
/// DESIGN.md §9): names are escaped, numbers printed in full.
#[derive(Default)]
pub struct JsonReport {
    records: Vec<(String, Timing)>,
}

impl JsonReport {
    pub fn new() -> Self {
        JsonReport::default()
    }

    /// Record one case's timing under `name`.
    pub fn record(&mut self, name: &str, t: Timing) {
        self.records.push((name.to_string(), t));
    }

    /// Serialize all records as a JSON array.
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, (name, t)) in self.records.iter().enumerate() {
            let escaped: String = name
                .chars()
                .flat_map(|c| match c {
                    '"' | '\\' => vec!['\\', c],
                    c if (c as u32) < 0x20 => vec![' '],
                    c => vec![c],
                })
                .collect();
            out.push_str(&format!(
                "  {{\"name\": \"{escaped}\", \"mean_secs\": {}, \"std_secs\": {}, \"iters\": {}}}{}\n",
                t.mean.as_secs_f64(),
                t.std_dev.as_secs_f64(),
                t.iters,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push(']');
        out
    }

    /// Write the report to `$ADAPTIVEC_BENCH_JSON` if that variable is
    /// set (the CI artifact path); a no-op otherwise.
    pub fn write_env(&self) -> std::io::Result<()> {
        if let Ok(path) = std::env::var("ADAPTIVEC_BENCH_JSON") {
            std::fs::write(&path, self.to_json())?;
            eprintln!("wrote bench JSON -> {path}");
        }
        Ok(())
    }
}

/// Best-effort raise of the process's open-file soft limit toward
/// `want` (clamped to the hard limit) — benches that hold thousands of
/// sockets at once outgrow the usual 1024-descriptor default. Raw
/// `extern "C"` syscall bindings, same zero-dependency pattern as the
/// mmap and epoll layers. Returns the soft limit in effect afterwards;
/// 0 means the limit could not even be read (treat as "unknown").
#[cfg(all(target_os = "linux", target_pointer_width = "64"))]
pub fn raise_nofile_limit(want: u64) -> u64 {
    #[repr(C)]
    struct Rlimit {
        rlim_cur: u64,
        rlim_max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    unsafe {
        let mut rl = Rlimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut rl) != 0 {
            return 0;
        }
        if rl.rlim_cur < want {
            let raised = Rlimit { rlim_cur: want.min(rl.rlim_max), rlim_max: rl.rlim_max };
            if setrlimit(RLIMIT_NOFILE, &raised) == 0 {
                return raised.rlim_cur;
            }
        }
        rl.rlim_cur
    }
}

/// Non-Linux fallback: no raw rlimit bindings, report "unknown".
#[cfg(not(all(target_os = "linux", target_pointer_width = "64")))]
pub fn raise_nofile_limit(_want: u64) -> u64 {
    0
}

/// Time `f`: `warmup` throwaway runs then `iters` measured runs.
pub fn bench<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Timing {
        mean: Duration::from_secs_f64(mean),
        std_dev: Duration::from_secs_f64(var.sqrt()),
        iters,
    }
}

/// Time a single run (for expensive end-to-end cases).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n=== {title} ===");
        let line = |ch: char| println!("{}", ch.to_string().repeat(total.min(160)));
        line('-');
        let mut hdr = String::from("|");
        for (h, w) in self.headers.iter().zip(&widths) {
            hdr.push_str(&format!(" {h:>w$} |"));
        }
        println!("{hdr}");
        line('-');
        for row in &self.rows {
            let mut r = String::from("|");
            for (c, w) in row.iter().zip(&widths) {
                r.push_str(&format!(" {c:>w$} |"));
            }
            println!("{r}");
        }
        line('-');
    }
}

/// Print an (x, series...) dataset the way the paper's figures plot it.
pub fn print_series(title: &str, x_label: &str, x: &[String], series: &[(&str, Vec<f64>)]) {
    let mut headers = vec![x_label];
    for (name, _) in series {
        headers.push(name);
    }
    let mut t = Table::new(&headers);
    for (i, xv) in x.iter().enumerate() {
        let mut row = vec![xv.clone()];
        for (_, ys) in series {
            row.push(format!("{:.3}", ys[i]));
        }
        t.row(&row);
    }
    t.print(title);
}

/// Relative speed of `new` vs `baseline` as a table cell, e.g.
/// `"1.73x"` (>1 = `new` is faster).
pub fn speedup(baseline: &Timing, new: &Timing) -> String {
    let n = new.mean.as_secs_f64();
    if n <= 0.0 {
        return "-".into();
    }
    format!("{:.2}x", baseline.mean.as_secs_f64() / n)
}

/// Human-readable byte count (KiB/MiB granularity for bench tables).
pub fn bytes_h(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// Format helpers.
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x)
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench(1, 5, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(t.mean > Duration::ZERO);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test table");
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn json_report_escapes_and_lists() {
        let mut r = JsonReport::new();
        let t = Timing {
            mean: Duration::from_millis(5),
            std_dev: Duration::from_millis(1),
            iters: 3,
        };
        r.record("plain", t);
        r.record("quo\"te\\back", t);
        let json = r.to_json();
        assert!(json.starts_with('[') && json.ends_with(']'), "{json}");
        assert!(json.contains("\"name\": \"plain\""), "{json}");
        assert!(json.contains("quo\\\"te\\\\back"), "{json}");
        assert!(json.contains("\"iters\": 3"), "{json}");
        // Exactly one separating comma between the two records.
        assert_eq!(json.matches("},").count(), 1, "{json}");
    }

    #[test]
    fn speedup_and_bytes_format() {
        let mk = |ms: u64| Timing {
            mean: Duration::from_millis(ms),
            std_dev: Duration::ZERO,
            iters: 1,
        };
        assert_eq!(speedup(&mk(200), &mk(100)), "2.00x");
        assert_eq!(speedup(&mk(100), &mk(0)), "-");
        assert_eq!(bytes_h(512), "512 B");
        assert_eq!(bytes_h(2048), "2.0 KiB");
        assert_eq!(bytes_h(3 << 20), "3.0 MiB");
    }

    #[test]
    fn overrides_fall_back_to_defaults() {
        // The env vars are unset in the test environment.
        assert_eq!(iters_override(7), 7);
        assert_eq!(scale_override(1), 1);
    }

    #[test]
    #[cfg(all(target_os = "linux", target_pointer_width = "64"))]
    fn nofile_limit_reads_and_never_shrinks() {
        // Asking for 1 fd never lowers the limit: the helper only ever
        // raises, so this just reads the current soft limit.
        let before = raise_nofile_limit(1);
        assert!(before >= 1, "soft limit must be readable");
        let again = raise_nofile_limit(before);
        assert_eq!(again, before, "idempotent at the current limit");
    }
}
