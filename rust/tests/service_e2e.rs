//! Service front-end end-to-end guarantees:
//!
//! * the in-process `ServiceHandle` round-trip is **byte-identical** to
//!   the offline `compress_chunked_to` + `load_field` path — both for
//!   decoded field data and, when a batch covers the same field set,
//!   for the container bytes themselves;
//! * admission control sheds load with `Busy` past the high-water mark
//!   and never loses or corrupts an *accepted* request;
//! * a shared `Engine` + `CachedSource`-backed reader serve concurrent
//!   readers byte-identically with coherent LRU hit/miss accounting.

use adaptivec::baseline::Policy;
use adaptivec::coordinator::store::{CachedSource, ContainerReader, FileSource};
use adaptivec::data::atm;
use adaptivec::data::field::Field;
use adaptivec::engine::{Engine, EngineConfig};
use adaptivec::service::{Request, Response, Service, ServiceConfig};
use adaptivec::Error;
use std::sync::Arc;

const EB: f64 = 1e-3;
const CHUNK: usize = 2048;

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() }))
}

fn svc_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        queue_depth: 64,
        batch_max: 4,
        eb_rel: EB,
        chunk_elems: CHUNK,
        ..ServiceConfig::default()
    }
}

fn fields(n: usize, seed: u64) -> Vec<Field> {
    (0..n).map(|i| atm::generate_field_scaled(seed, i, 0)).collect()
}

/// Offline reference: the same engine, the same policy knobs, no
/// service in between.
fn offline_decode(engine: &Engine, fields: &[Field]) -> Vec<Field> {
    let (_, bytes) = engine
        .compress_chunked_to(fields, Policy::RateDistortion, EB, CHUNK, Vec::new())
        .unwrap();
    let reader = ContainerReader::from_bytes(bytes).unwrap();
    fields.iter().map(|f| engine.load_field(&reader, &f.name).unwrap()).collect()
}

/// Poll the handle's report until the queue is empty (the stall job
/// was picked up) — makes the single-batch tests deterministic.
fn wait_queue_drained(handle: &adaptivec::service::ServiceHandle) {
    for _ in 0..200 {
        if handle.report().queue_depth == 0 {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("queue never drained");
}

#[test]
fn handle_roundtrip_is_byte_identical_to_offline_path() {
    let engine = engine();
    let svc = Service::start(Arc::clone(&engine), svc_cfg()).unwrap();
    let handle = svc.handle();
    let fields = fields(6, 91);

    // Pipeline all submissions, then collect — lets batches form.
    let tickets: Vec<_> = fields
        .iter()
        .map(|f| handle.submit(Request::Compress { field: f.clone() }).unwrap())
        .collect();
    for (t, f) in tickets.into_iter().zip(&fields) {
        match t.wait().unwrap() {
            Response::Compressed { name, raw_bytes, .. } => {
                assert_eq!(name, f.name);
                assert_eq!(raw_bytes, f.raw_bytes() as u64);
            }
            other => panic!("unexpected response {other:?}"),
        }
    }

    // Every fetched field is bit-identical to the offline decode —
    // regardless of how the service happened to batch the requests,
    // because chunk decisions depend only on the field's own data.
    let offline = offline_decode(&engine, &fields);
    for (f, off) in fields.iter().zip(&offline) {
        let served = handle.fetch(&f.name).unwrap();
        assert_eq!(served.dims, off.dims, "{}", f.name);
        assert_eq!(served.data, off.data, "{}: served decode differs from offline", f.name);
    }
    let report = svc.shutdown();
    assert_eq!(report.rejected, 0);
    assert_eq!(report.completed, 12);
}

#[test]
fn one_coalesced_batch_reproduces_offline_container_bytes() {
    let engine = engine();
    let svc = Service::start(
        Arc::clone(&engine),
        ServiceConfig { workers: 1, batch_max: 16, ..svc_cfg() },
    )
    .unwrap();
    let handle = svc.handle();
    let fields = fields(4, 92);

    // Occupy the single worker, then queue every compress behind it so
    // one drain coalesces them all into one store pass.
    let stall = handle.submit(Request::Stall { millis: 300 }).unwrap();
    wait_queue_drained(&handle);
    let tickets: Vec<_> = fields
        .iter()
        .map(|f| handle.submit(Request::Compress { field: f.clone() }).unwrap())
        .collect();
    stall.wait().unwrap();
    for t in tickets {
        match t.wait().unwrap() {
            Response::Compressed { batch_size, .. } => assert_eq!(batch_size, fields.len()),
            other => panic!("unexpected response {other:?}"),
        }
    }

    // The batch's archived container is byte-identical to the offline
    // compress_chunked_to output for the same fields in the same order.
    let (_, offline_bytes) = engine
        .compress_chunked_to(&fields, Policy::RateDistortion, EB, CHUNK, Vec::new())
        .unwrap();
    let records = svc.batch_containers();
    assert_eq!(records.len(), 1, "all four compresses must share one store pass");
    let names: Vec<String> = fields.iter().map(|f| f.name.clone()).collect();
    assert_eq!(records[0].names, names);
    assert_eq!(
        records[0].bytes, offline_bytes,
        "service batch container must be byte-identical to the offline writer"
    );
    svc.shutdown();
}

#[test]
fn over_capacity_burst_rejects_busy_without_losing_accepted_requests() {
    let engine = engine();
    let svc = Service::start(
        Arc::clone(&engine),
        ServiceConfig { workers: 1, queue_depth: 2, batch_max: 1, ..svc_cfg() },
    )
    .unwrap();
    let handle = svc.handle();

    // Pin the only worker, deterministically, then burst far past the
    // 2-slot queue.
    let stall = handle.submit(Request::Stall { millis: 400 }).unwrap();
    wait_queue_drained(&handle);
    let mut accepted: Vec<(Field, adaptivec::service::Ticket)> = Vec::new();
    let mut busy = 0u64;
    for i in 0..20usize {
        let mut field = atm::generate_field_scaled(93, i % 8, 0);
        field.name = format!("burst{i}");
        match handle.submit(Request::Compress { field: field.clone() }) {
            Ok(t) => accepted.push((field, t)),
            Err(Error::Busy) => busy += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(busy >= 1, "the burst must observe at least one Busy rejection");
    assert!(!accepted.is_empty(), "admission must accept up to the high-water mark");
    assert!(accepted.len() <= 2, "never more than queue_depth in flight");
    stall.wait().unwrap();

    // Every *accepted* request completes and round-trips bit-exactly
    // against the offline path — shedding lost nothing that was
    // admitted, and corrupted nothing.
    for (field, ticket) in accepted {
        match ticket.wait().unwrap() {
            Response::Compressed { name, .. } => assert_eq!(name, field.name),
            other => panic!("unexpected response {other:?}"),
        }
        let served = handle.fetch(&field.name).unwrap();
        let offline = offline_decode(&engine, std::slice::from_ref(&field));
        assert_eq!(served.data, offline[0].data, "{}", field.name);
    }

    let report = svc.shutdown();
    assert_eq!(report.rejected, busy);
    assert!(report.queue_peak <= 2, "admission bound held");
    assert_eq!(report.errors, 0);
}

#[test]
fn concurrent_readers_share_a_cached_archive_byte_identically() {
    let engine = engine();
    let fields = fields(4, 94);
    let path = std::env::temp_dir().join("adaptivec_service_e2e_cached.adaptivec2");
    {
        let sink = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        engine
            .compress_chunked_to(&fields, Policy::RateDistortion, EB, CHUNK, sink)
            .unwrap();
    }

    // One pread file source behind one LRU cache, shared by N threads
    // through one reader and one engine.
    let file = Arc::new(FileSource::open(&path).unwrap());
    let cached = Arc::new(CachedSource::new(file, 64 << 20));
    let reader = ContainerReader::from_source(cached.clone()).unwrap();
    let baseline = engine.load_reader(&reader).unwrap();
    let total_chunks: usize = reader.fields.iter().map(|f| f.chunks.len()).sum();
    assert!(total_chunks > fields.len(), "chunked archive expected");
    let (h0, m0) = cached.stats();
    assert!(m0 > 0, "the warmup pass reads through the cache");

    let threads = 4usize;
    let iters = 3usize;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let engine = &engine;
            let reader = &reader;
            let baseline = &baseline;
            scope.spawn(move || {
                for _ in 0..iters {
                    for expect in baseline {
                        let got = engine.load_field(reader, &expect.name).unwrap();
                        assert_eq!(got.dims, expect.dims, "{}", expect.name);
                        assert_eq!(
                            got.data, expect.data,
                            "{}: concurrent load diverged",
                            expect.name
                        );
                    }
                }
            });
        }
    });

    // Coherent cache accounting: the hammer phase was all hits (the
    // warm cache holds every chunk range), one per chunk decode.
    let (h1, m1) = cached.stats();
    assert_eq!(m1, m0, "no new misses once warm");
    assert_eq!(
        h1 - h0,
        (threads * iters * total_chunks) as u64,
        "every concurrent chunk read must be served by the cache"
    );
    std::fs::remove_file(&path).ok();
}
