//! Crash-consistency torture (DESIGN.md §16): re-exec this test as a
//! child process, abort it at a seeded failpoint inside the archive
//! spill publish protocol, then reopen the archive root and assert the
//! recovery invariants:
//!
//! * open never panics and counts zero corrupt shards — a crash can
//!   only leave a swept `.tmp.` orphan or a fully published shard,
//!   never a half-indexed one;
//! * the recovered field set is exactly the batches whose publish
//!   completed before the abort (a strict prefix of the insert order),
//!   with last-write-wins when a re-compressed name's later shard
//!   survived;
//! * every surviving field decodes byte-identical to the offline
//!   reference compression of the same field;
//! * the reopened archive accepts fresh inserts.
//!
//! Requires `--features faults`: the kill policy lives in the
//! failpoint layer and arms through `ADAPTIVEC_FAILPOINTS`, exactly
//! the path a CI e2e run uses against a real binary.

#![cfg(feature = "faults")]

use adaptivec::baseline::Policy;
use adaptivec::data::atm;
use adaptivec::data::field::Field;
use adaptivec::engine::{Engine, EngineConfig};
use adaptivec::service::{ArchiveConfig, ArchiveStore};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// When set, this process is the torture child: run the workload
/// against the given archive root (the seeded failpoint aborts us
/// somewhere in the middle).
const CHILD_ENV: &str = "ADAPTIVEC_CRASH_CHILD_ROOT";

const EB: f64 = 1e-3;
const CHUNK: usize = 2048;

fn engine() -> Engine {
    Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })
}

fn archive_cfg(root: &Path) -> ArchiveConfig {
    // Inline spills: the kill_nth fault points must fire on the
    // inserting thread at deterministic call counts.
    ArchiveConfig {
        root_dir: Some(root.to_path_buf()),
        mem_budget: 0,
        open_readers: 4,
        background_spill: false,
    }
}

/// The deterministic workload both lives agree on: six single-field
/// batches with unique names, then a seventh batch re-compressing the
/// first name with different data (the last-write-wins probe). With a
/// zero memory budget each insert publishes its shard before the next
/// starts, so failpoint hit `k` always lands in batch `k`.
fn workload() -> Vec<Field> {
    let mut fields = Vec::new();
    for i in 0..6u64 {
        let mut f = atm::generate_field_scaled(90 + i, (i % 4) as usize, 0);
        f.name = format!("torture-{i:02}");
        fields.push(f);
    }
    let mut dup = atm::generate_field_scaled(99, 1, 0);
    dup.name = "torture-00".into();
    fields.push(dup);
    fields
}

fn pack(engine: &Engine, f: &Field) -> (Vec<String>, Vec<u8>) {
    let (_, bytes) = engine
        .compress_chunked_to(
            std::slice::from_ref(f),
            Policy::RateDistortion,
            EB,
            CHUNK,
            Vec::new(),
        )
        .unwrap();
    (vec![f.name.clone()], bytes)
}

/// Offline reference decode — what a surviving shard must serve,
/// byte for byte.
fn offline(engine: &Engine, f: &Field) -> Field {
    let (_, bytes) = pack(engine, f);
    let reader = adaptivec::coordinator::store::ContainerReader::from_bytes(bytes).unwrap();
    engine.load_field(&reader, &f.name).unwrap()
}

/// Child branch: insert the workload until the seeded kill aborts us.
/// Exits 0 only if no failpoint fired — the parent asserts it never
/// gets that far.
fn run_child(root: &Path) -> ! {
    let engine = engine();
    let store = ArchiveStore::open(archive_cfg(root), 8).expect("child open");
    for f in workload() {
        let (names, bytes) = pack(&engine, &f);
        store.insert(names, bytes).expect("child insert");
    }
    std::process::exit(0);
}

fn assert_no_stray_tmp(root: &Path, ctx: &str) {
    for dir in std::fs::read_dir(root).unwrap() {
        let dir = dir.unwrap().path();
        if !dir.is_dir() {
            continue;
        }
        for f in std::fs::read_dir(&dir).unwrap() {
            let p = f.unwrap().path();
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            assert!(!name.contains(".tmp."), "{ctx}: stray temp file {p:?} survived recovery");
        }
    }
}

#[test]
fn crash_torture_recovers_at_every_kill_point() {
    if let Ok(root) = std::env::var(CHILD_ENV) {
        run_child(Path::new(&root));
    }

    // Kill points across every stage of the publish protocol, early
    // and late in the workload. Hits are 1-based per site; with one
    // batch per hit, `publish` at hit n dies *after* batch n's rename
    // (n batches live), every other site dies *before* batch n
    // publishes (n-1 batches live).
    let kill_points: &[(&str, u64)] = &[
        ("archive.spill.stage", 1),
        ("archive.spill.temp_write", 1),
        ("archive.spill.temp_write", 4),
        ("archive.spill.fsync", 2),
        ("archive.spill.fsync", 6),
        ("archive.spill.rename", 3),
        ("archive.spill.rename", 7),
        ("archive.spill.publish", 2),
        ("archive.spill.publish", 5),
        ("archive.spill.publish", 7),
    ];

    let exe = std::env::current_exe().unwrap();
    let engine = engine();
    let fields = workload();

    for (point, &(site, n)) in kill_points.iter().enumerate() {
        let ctx = format!("kill point {point} ({site}:kill_nth({n}))");
        let root: PathBuf =
            std::env::temp_dir().join(format!("adaptivec_crash_{point}_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();

        // Re-exec ourselves as the torture child, aborted at the seed.
        let out = std::process::Command::new(&exe)
            .arg("crash_torture_recovers_at_every_kill_point")
            .arg("--exact")
            .arg("--test-threads=1")
            .env(CHILD_ENV, &root)
            .env("ADAPTIVEC_FAILPOINTS", format!("{site}:kill_nth({n})"))
            .output()
            .expect("spawn torture child");
        assert!(
            !out.status.success(),
            "{ctx}: the child must die at the failpoint, not finish \
             (stderr: {})",
            String::from_utf8_lossy(&out.stderr)
        );

        // Reopen: recovery must never panic, never count corruption,
        // and must sweep any torn temp file the abort left behind.
        let store = ArchiveStore::open(archive_cfg(&root), 8)
            .unwrap_or_else(|e| panic!("{ctx}: reopen failed: {e}"));
        let stats = store.stats();
        assert_eq!(stats.corrupt_shards, 0, "{ctx}: a crash must not publish a torn shard");
        assert_no_stray_tmp(&root, &ctx);

        // Exactly the batches published before the abort survive,
        // with last-write-wins on the re-compressed name.
        let published = (if site == "archive.spill.publish" { n } else { n - 1 }) as usize;
        let mut expect: BTreeMap<String, &Field> = BTreeMap::new();
        for f in fields.iter().take(published) {
            expect.insert(f.name.clone(), f);
        }
        let mut names = store.field_names();
        names.sort();
        let want: Vec<String> = expect.keys().cloned().collect();
        assert_eq!(names, want, "{ctx}: recovered field set");
        assert_eq!(stats.recovered_fields as usize, expect.len(), "{ctx}");
        for (name, f) in &expect {
            let reader = store
                .reader_for(name)
                .unwrap_or_else(|e| panic!("{ctx}: reader for {name}: {e}"))
                .unwrap_or_else(|| panic!("{ctx}: {name} indexed but unreadable"));
            let served = engine.load_field(&reader, name).unwrap();
            let want = offline(&engine, f);
            assert_eq!(
                served.data, want.data,
                "{ctx}: {name} must decode byte-identical to the offline path"
            );
        }
        if published == fields.len() {
            // The dup batch won "torture-00": its superseded original
            // shard serves nothing and the open must have deleted it.
            assert!(stats.superseded_deleted >= 1, "{ctx}: superseded sweep");
        }

        // The survivor keeps working: a fresh insert publishes and
        // serves through the same archive.
        let mut extra = atm::generate_field_scaled(123, 2, 0);
        extra.name = "torture-extra".into();
        let (extra_names, bytes) = pack(&engine, &extra);
        store.insert(extra_names, bytes).unwrap_or_else(|e| panic!("{ctx}: fresh insert: {e}"));
        let reader = store.reader_for("torture-extra").unwrap().expect("fresh field indexed");
        let served = engine.load_field(&reader, "torture-extra").unwrap();
        assert_eq!(served.data, offline(&engine, &extra).data, "{ctx}: fresh insert roundtrip");

        drop(store);
        std::fs::remove_dir_all(&root).ok();
    }
}
