//! Property tests for the DCT codec (selection byte 3): round-trip
//! and pointwise error-bound compliance on randomly shaped 1D/2D/3D
//! fields, including partial edge blocks, plus determinism and
//! registry-framing checks.
//!
//! Bound slack: the codec's guarantee is the orthogonality argument
//! |x̃−x|∞ ≤ (δ_c/2)·√(4ⁿ) = eb, on top of which escaped coefficients
//! round through f32 (~1e-7 relative). The generated eb is ≥ 1e-3 of
//! the value range, so a 1% slack dominates both effects while still
//! failing on any real quantizer bug.

use adaptivec::codec_api::{Choice, CodecRegistry};
use adaptivec::data::field::Dims;
use adaptivec::dct::DctCompressor;
use adaptivec::metrics::error_stats;
use adaptivec::testing::proptest_lite::{forall, Gen};

#[derive(Clone, Debug)]
struct Case {
    data: Vec<f32>,
    dims: Dims,
    eb: f64,
}

fn gen_case() -> Gen<Case> {
    Gen::new(|r| {
        let dims = match r.below(3) {
            0 => Dims::D1(r.range(1, 600)),
            1 => Dims::D2(r.range(1, 40), r.range(1, 40)),
            _ => Dims::D3(r.range(1, 14), r.range(1, 14), r.range(1, 14)),
        };
        let n = dims.len();
        let scale = r.range_f64(1e-2, 1e3);
        let smooth = r.bool(0.5);
        let mut walk = r.range_f64(-1.0, 1.0) * scale;
        let data: Vec<f32> = (0..n)
            .map(|_| {
                if smooth {
                    walk += r.gauss() * 0.02 * scale;
                    walk as f32
                } else {
                    r.range_f64(-scale, scale) as f32
                }
            })
            .collect();
        let (mn, mx) = data
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)));
        let vr = (mx - mn) as f64;
        let eb_rel = if r.bool(0.5) { 1e-2 } else { 1e-3 };
        let eb = if vr > 0.0 { eb_rel * vr } else { eb_rel };
        Case { data, dims, eb }
    })
}

#[test]
fn dct_roundtrip_respects_bound_on_all_dims() {
    forall("DCT round-trip within pointwise bound", 120, gen_case(), |c| {
        let dct = DctCompressor::default();
        let comp = dct.compress(&c.data, c.dims, c.eb).unwrap();
        let (recon, rdims) = dct.decompress(&comp).unwrap();
        rdims == c.dims
            && recon.len() == c.data.len()
            && error_stats(&c.data, &recon).max_abs_err <= c.eb * 1.01
    });
}

#[test]
fn dct_compression_is_deterministic() {
    forall("DCT compression is deterministic", 40, gen_case(), |c| {
        let dct = DctCompressor::default();
        let a = dct.compress(&c.data, c.dims, c.eb).unwrap();
        let b = dct.compress(&c.data, c.dims, c.eb).unwrap();
        a == b
    });
}

#[test]
fn dct_registry_payloads_roundtrip() {
    // Selection byte 3 framing through the registry: encode prefixes
    // the byte, decode dispatches on it.
    let registry = CodecRegistry::default();
    forall("DCT registry framing round-trips", 40, gen_case(), |c| {
        let payload = registry.encode(Choice::Dct, &c.data, c.dims, c.eb).unwrap();
        if payload[0] != Choice::Dct.id() {
            return false;
        }
        let (recon, rdims) = registry.decode(&payload).unwrap();
        rdims == c.dims && error_stats(&c.data, &recon).max_abs_err <= c.eb * 1.01
    });
}

#[test]
fn dct_truncated_streams_error_not_panic() {
    let dct = DctCompressor::default();
    let data: Vec<f32> = (0..4096).map(|i| ((i % 97) as f32 * 0.37).sin() * 7.0).collect();
    let comp = dct.compress(&data, Dims::D2(64, 64), 1e-3).unwrap();
    for len in 0..comp.len().min(512) {
        assert!(dct.decompress(&comp[..len]).is_err(), "prefix {len} parsed");
    }
    // Bit flips in the header region must be total (Ok or Err, no
    // panic); decoded output under a flipped header carries no
    // guarantee, only memory safety.
    for pos in 0..comp.len().min(64) {
        for bit in 0..8 {
            let mut bad = comp.clone();
            bad[pos] ^= 1 << bit;
            let _ = dct.decompress(&bad);
        }
    }
}
