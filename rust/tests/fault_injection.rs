//! End-to-end failure hardening under deterministic fault injection
//! (DESIGN.md §16). Requires `--features faults` — without it the
//! failpoint layer compiles to a no-op stub and this whole file is
//! compiled out.
//!
//! Covered here:
//! * transient `EIO` on the spill temp write is absorbed by the
//!   bounded retry loop (counted in `io_retries`, archive healthy);
//! * `ENOSPC` flips the archive into degraded memory-only mode —
//!   inserts keep succeeding, eviction pauses, and the flag clears
//!   (counted as a recovery) once writes succeed again;
//! * a torn (short) temp write is retried and never leaves a stray
//!   temp file or a torn published shard behind;
//! * a panic inside worker batch execution resolves the tickets with
//!   `Error::Internal` while the worker survives and keeps serving;
//! * mmap/pread faults on the cold-read path surface as errors (or
//!   fall back), never panics;
//! * a slow-loris dribbler (one mid-frame byte per tick, every byte
//!   inside the per-read window) is torn down by the reactor's pinned
//!   read deadline while a well-behaved connection keeps being served
//!   and injected `net.poll_wait` faults are absorbed by the loop.

#![cfg(feature = "faults")]

use adaptivec::baseline::Policy as CodecPolicy;
use adaptivec::data::atm;
use adaptivec::data::field::Field;
use adaptivec::engine::{Engine, EngineConfig};
use adaptivec::service::net::{Client, NetConfig, Server};
use adaptivec::service::{reactor, ArchiveConfig, ArchiveStore, Service, ServiceConfig};
use adaptivec::testing::failpoints::{self, Errno, Policy};
use adaptivec::Error;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

const EB: f64 = 1e-3;
const CHUNK: usize = 2048;

/// The failpoint registry is process-global and the test harness runs
/// tests in parallel: every test that arms a site holds this lock (and
/// disarms before releasing), so injections never leak across tests.
fn serialize() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn engine() -> Engine {
    Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })
}

fn temp_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("adaptivec_faults_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn archive_cfg(root: &Path) -> ArchiveConfig {
    // Inline spills: these tests assert retry/degraded counters
    // immediately after each insert.
    ArchiveConfig {
        root_dir: Some(root.to_path_buf()),
        mem_budget: 0,
        open_readers: 4,
        background_spill: false,
    }
}

/// Compress one field exactly the way the tests insert it.
fn pack(engine: &Engine, f: &Field) -> (Vec<String>, Vec<u8>) {
    let (_, bytes) = engine
        .compress_chunked_to(
            std::slice::from_ref(f),
            CodecPolicy::RateDistortion,
            EB,
            CHUNK,
            Vec::new(),
        )
        .unwrap();
    (vec![f.name.clone()], bytes)
}

/// Offline reference decode — the byte-identity yardstick.
fn offline(engine: &Engine, f: &Field) -> Field {
    let (_, bytes) = pack(engine, f);
    let reader = adaptivec::coordinator::store::ContainerReader::from_bytes(bytes).unwrap();
    engine.load_field(&reader, &f.name).unwrap()
}

fn fetch(engine: &Engine, store: &ArchiveStore, name: &str) -> Field {
    let reader = store.reader_for(name).unwrap().expect("field indexed");
    engine.load_field(&reader, name).unwrap()
}

fn assert_no_stray_tmp(root: &Path) {
    for dir in std::fs::read_dir(root).unwrap() {
        let dir = dir.unwrap().path();
        if !dir.is_dir() {
            continue;
        }
        for f in std::fs::read_dir(&dir).unwrap() {
            let p = f.unwrap().path();
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            assert!(!name.contains(".tmp."), "stray temp file {p:?} left behind");
        }
    }
}

#[test]
fn transient_eio_on_spill_is_retried_and_absorbed() {
    let _guard = serialize();
    let engine = engine();
    let root = temp_root("eio");
    let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
    let field = atm::generate_field_scaled(60, 0, 0);

    // First temp write fails with EIO; the retry loop's second attempt
    // must publish the shard as if nothing happened.
    failpoints::arm("archive.spill.temp_write", Policy::FailNth(1));
    let (names, bytes) = pack(&engine, &field);
    store.insert(names, bytes).unwrap();
    failpoints::disarm("archive.spill.temp_write");

    let stats = store.stats();
    assert_eq!(stats.spills, 1, "spill must succeed on retry");
    assert!(stats.io_retries >= 1, "the transient failure must be counted");
    assert!(!stats.degraded, "a retried transient is not a degraded episode");
    assert_eq!(stats.hot_bytes, 0, "budget 0 must evict after the spill");
    assert_no_stray_tmp(&root);
    assert_eq!(fetch(&engine, &store, &field.name).data, offline(&engine, &field).data);
    drop(store);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn enospc_degrades_to_memory_only_then_recovers() {
    let _guard = serialize();
    let engine = engine();
    let root = temp_root("enospc");
    let store = ArchiveStore::open(archive_cfg(&root), 8).unwrap();
    let fields: Vec<Field> = (0..3).map(|i| atm::generate_field_scaled(61, i, 0)).collect();

    // Every write fails with ENOSPC: not transient, so the archive
    // must flip degraded — and *inserts must keep succeeding*.
    failpoints::arm("archive.spill.temp_write", Policy::ErrEvery(1, Errno::Enospc));
    for f in &fields[..2] {
        let (names, bytes) = pack(&engine, f);
        store.insert(names, bytes).unwrap();
    }
    let stats = store.stats();
    assert!(stats.degraded, "hard ENOSPC must degrade the archive");
    assert_eq!(stats.degraded_events, 1, "one episode, however many failures");
    assert_eq!(stats.spills, 0);
    assert!(stats.hot_bytes > 0, "eviction pauses: batches stay resident");
    if cfg!(unix) {
        assert!(
            stats.degraded_reason.contains("out of space"),
            "reason must name the cause: {}",
            stats.degraded_reason
        );
    }
    // Degraded reads still work — both batches are hot.
    assert_eq!(fetch(&engine, &store, &fields[0].name).data, offline(&engine, &fields[0]).data);

    // Device recovers: the next insert's probe spill must succeed,
    // clear the flag, and drain the whole backlog.
    failpoints::disarm("archive.spill.temp_write");
    let (names, bytes) = pack(&engine, &fields[2]);
    store.insert(names, bytes).unwrap();
    let stats = store.stats();
    assert!(!stats.degraded, "flag must clear once writes recover");
    assert_eq!(stats.degraded_recoveries, 1);
    assert_eq!(stats.spills, 3, "the backlog must drain, not just the probe");
    assert_eq!(stats.hot_bytes, 0);
    for f in &fields {
        assert_eq!(fetch(&engine, &store, &f.name).data, offline(&engine, f).data, "{}", f.name);
    }
    assert_no_stray_tmp(&root);
    drop(store);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn torn_temp_write_is_retried_and_publishes_whole_bytes() {
    let _guard = serialize();
    let engine = engine();
    let root = temp_root("torn");
    let field = atm::generate_field_scaled(62, 1, 0);
    {
        let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
        // First attempt writes only 40% of the shard then errors; the
        // retry must start over and publish the full container.
        failpoints::arm("archive.spill.temp_write", Policy::ShortWrite(0.4));
        let (names, bytes) = pack(&engine, &field);
        store.insert(names, bytes).unwrap();
        failpoints::disarm("archive.spill.temp_write");
        let stats = store.stats();
        assert_eq!(stats.spills, 1);
        assert!(stats.io_retries >= 1);
        assert_no_stray_tmp(&root);
    }
    // A fresh open proves it from disk: the shard indexes cleanly and
    // decodes byte-identical — nothing torn was ever published.
    let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
    let stats = store.stats();
    assert_eq!(stats.corrupt_shards, 0, "a torn write must never publish");
    assert_eq!(stats.recovered_fields, 1);
    assert_eq!(fetch(&engine, &store, &field.name).data, offline(&engine, &field).data);
    drop(store);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn worker_panic_resolves_tickets_and_worker_survives() {
    let _guard = serialize();
    let cfg = ServiceConfig {
        workers: 1,
        eb_rel: EB,
        chunk_elems: CHUNK,
        ..ServiceConfig::default()
    };
    let svc = Service::start(Arc::new(engine()), cfg).unwrap();
    let handle = svc.handle();
    let field = atm::generate_field_scaled(63, 0, 0);

    failpoints::arm("service.batch", Policy::PanicOnce);
    let err = handle.compress(field.clone()).expect_err("the panicking pass must fail the ticket");
    failpoints::disarm("service.batch");
    match &err {
        Error::Internal(m) => assert!(m.contains("panicked"), "{m}"),
        other => panic!("expected Error::Internal, got {other:?}"),
    }

    // The same (sole) worker keeps serving: the next compress and a
    // fetch both succeed, and the report shows the contained panic.
    handle.compress(field.clone()).unwrap();
    assert_eq!(handle.fetch(&field.name).unwrap().dims, field.dims);
    let report = handle.report();
    assert_eq!(report.worker_panics, 1, "{}", report.summary());
    assert_eq!(report.workers_alive, 1, "{}", report.summary());
    assert!(report.summary().contains("worker_panics 1"));
    svc.shutdown();
}

#[test]
fn cold_read_faults_error_or_fall_back_never_panic() {
    let _guard = serialize();
    let engine = engine();
    let root = temp_root("coldread");
    let field = atm::generate_field_scaled(64, 2, 0);
    {
        let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
        let (names, bytes) = pack(&engine, &field);
        store.insert(names, bytes).unwrap();
        assert_eq!(store.stats().spills, 1);
    }

    // mmap refused: open_cached must fall back to the pread source and
    // the fetch must still decode byte-identically.
    {
        let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
        failpoints::arm("store.mmap", Policy::ErrEvery(1, Errno::Eio));
        let got = fetch(&engine, &store, &field.name);
        failpoints::disarm("store.mmap");
        assert_eq!(got.data, offline(&engine, &field).data, "pread fallback must serve");
    }

    // Every positioned read failing: the fetch must surface an error —
    // not a panic, not wrong bytes.
    {
        let store = ArchiveStore::open(archive_cfg(&root), 4).unwrap();
        failpoints::arm("store.mmap", Policy::ErrEvery(1, Errno::Eio));
        failpoints::arm("store.pread", Policy::ErrEvery(1, Errno::Eio));
        let outcome = store.reader_for(&field.name).and_then(|r| match r {
            Some(reader) => engine.load_field(&reader, &field.name).map(|_| ()),
            None => Ok(()),
        });
        failpoints::disarm("store.mmap");
        failpoints::disarm("store.pread");
        assert!(outcome.is_err(), "unreadable cold shard must error cleanly");
        // Faults cleared: the same store serves the field again.
        assert_eq!(fetch(&engine, &store, &field.name).data, offline(&engine, &field).data);
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn slow_loris_dribbler_is_closed_without_stalling_others() {
    let _guard = serialize();
    // Only the readiness reactor pins a connection's read deadline at
    // the first byte of a partial frame; the thread path's per-read
    // socket timeouts reset on every byte, so a dribbler keeps those
    // alive by design. Nothing to assert without epoll.
    if !reactor::epoll_enabled() {
        return;
    }
    let eng = engine();
    let svc = Service::start(
        Arc::new(engine()),
        ServiceConfig { workers: 1, eb_rel: EB, chunk_elems: CHUNK, ..ServiceConfig::default() },
    )
    .unwrap();
    let server = Server::bind_with(
        svc.handle(),
        "127.0.0.1:0",
        NetConfig { read_timeout: Duration::from_millis(200), ..NetConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let acceptor = std::thread::spawn(move || server.run());

    // The reactor loop must also shrug off injected poll faults while
    // it polices the dribbler (each skips exactly one epoll_wait).
    failpoints::arm("net.poll_wait", Policy::ErrEvery(25, Errno::Eio));

    // The dribbler declares a plausible 64-byte frame, then feeds one
    // body byte per 20 ms tick — every byte lands well inside the
    // 200 ms window, so a per-read timeout would never fire.
    let mut loris = std::net::TcpStream::connect(&addr).unwrap();
    loris.set_nodelay(true).ok();
    loris.write_all(&64u32.to_le_bytes()).unwrap();
    let t0 = std::time::Instant::now();
    let dribble = std::thread::spawn(move || {
        let mut write_failed = false;
        for _ in 0..200 {
            if loris.write_all(&[0x5a]).is_err() {
                write_failed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let closed = write_failed || {
            // Writes can outlive the server-side close by a round trip
            // (the first write after the FIN only provokes the RST); a
            // read makes the teardown unambiguous. A timeout here means
            // the connection is still open — i.e. the defense failed.
            loris.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let mut b = [0u8; 1];
            match loris.read(&mut b) {
                Ok(0) => true,
                Ok(_) => false,
                Err(e) => !matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ),
            }
        };
        (closed, t0.elapsed())
    });

    // While the loris dribbles, a well-behaved connection round-trips
    // a compress, a byte-identical fetch, and a stats frame: the
    // stalled partial frame pins neither the reactor nor the worker.
    let field = atm::generate_field_scaled(77, 0, 0);
    let mut client = Client::connect(&addr).unwrap();
    let ack = client.compress(&field).unwrap();
    assert_eq!(ack.name, field.name);
    let got = client.fetch(&field.name).unwrap();
    assert_eq!(got.data, offline(&eng, &field).data, "served bytes must match offline");
    assert!(client.stats().unwrap().contains("transport:"));

    let (closed, waited) = dribble.join().unwrap();
    failpoints::disarm("net.poll_wait");
    assert!(closed, "the dribbling connection must be torn down by the read deadline");
    assert!(
        waited >= Duration::from_millis(150),
        "torn down after {waited:?} — before the pinned deadline could have fired"
    );

    client.shutdown().unwrap();
    acceptor.join().unwrap().unwrap();
    svc.shutdown();
}
