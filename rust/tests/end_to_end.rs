//! Integration: full pipeline over real (synthetic) datasets through
//! the coordinator, on-disk container, and back — every policy, every
//! dataset, error bounds verified pointwise.

use adaptivec::baseline::Policy;
use adaptivec::coordinator::{store::Container, Coordinator};
use adaptivec::data::Dataset;
use adaptivec::estimator::selector::SelectorConfig;
use adaptivec::metrics::error_stats;

fn roundtrip_dataset(ds: Dataset, policy: Policy, eb_rel: f64) {
    let coord = Coordinator::new(SelectorConfig::default(), 4);
    let fields = ds.generate(7, 0);
    let report = coord.run(&fields, policy, eb_rel).unwrap();
    assert_eq!(report.results.len(), fields.len());

    let dir = std::env::temp_dir().join("adaptivec_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}_{}_{}.bin", ds.name(), policy.name(), eb_rel));
    report.to_container().write_file(&path).unwrap();
    let container = Container::read_file(&path).unwrap();
    std::fs::remove_file(&path).ok();

    if policy == Policy::NoCompression {
        assert_eq!(container.stored_bytes(), container.raw_bytes());
        return;
    }
    let restored = coord.load(&container).unwrap();
    for (orig, rest) in fields.iter().zip(&restored) {
        assert_eq!(orig.name, rest.name);
        assert_eq!(orig.dims, rest.dims);
        let vr = orig.value_range();
        let bound = if vr > 0.0 { eb_rel * vr } else { eb_rel };
        let stats = error_stats(&orig.data, &rest.data);
        assert!(
            stats.max_abs_err <= bound * (1.0 + 1e-6),
            "{} / {} / {}: max err {} > bound {}",
            ds.name(),
            policy.name(),
            orig.name,
            stats.max_abs_err,
            bound
        );
    }
}

#[test]
fn nyx_all_policies() {
    for p in Policy::ALL {
        roundtrip_dataset(Dataset::Nyx, p, 1e-3);
    }
}

#[test]
fn atm_rate_distortion_policy() {
    roundtrip_dataset(Dataset::Atm, Policy::RateDistortion, 1e-3);
}

#[test]
fn hurricane_rate_distortion_policy() {
    roundtrip_dataset(Dataset::Hurricane, Policy::RateDistortion, 1e-3);
}

#[test]
fn tight_bound_still_holds() {
    roundtrip_dataset(Dataset::Hurricane, Policy::RateDistortion, 1e-6);
}

#[test]
fn loose_bound_compresses_harder() {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fields = Dataset::Atm.generate(7, 0);
    let loose = coord.run(&fields, Policy::RateDistortion, 1e-2).unwrap();
    let tight = coord.run(&fields, Policy::RateDistortion, 1e-5).unwrap();
    assert!(loose.overall_ratio() > tight.overall_ratio());
}

#[test]
fn selection_beats_worst_fixed_policy() {
    // The paper's headline property at dataset level: the automatic
    // selection's overall ratio is at least that of the worse fixed
    // codec (it can't lose to the worst choice).
    let coord = Coordinator::new(SelectorConfig::default(), 4);
    for ds in Dataset::ALL {
        let fields = ds.generate(7, 1);
        let sz = coord.run(&fields, Policy::AlwaysSz, 1e-4).unwrap().overall_ratio();
        let zfp = coord.run(&fields, Policy::AlwaysZfp, 1e-4).unwrap().overall_ratio();
        let ours = coord.run(&fields, Policy::RateDistortion, 1e-4).unwrap().overall_ratio();
        let worst = sz.min(zfp);
        assert!(
            ours >= worst * 0.98,
            "{}: ours {ours:.2} vs worst fixed {worst:.2}",
            ds.name()
        );
    }
}

#[test]
fn optimum_dominates_ours() {
    // The Optimum policy is the paper's *two-way* oracle, so compare
    // it against the two-way selector — the three-way selector may
    // legitimately beat it when DCT wins a field.
    use adaptivec::estimator::selector::CandidateSet;
    let cfg = SelectorConfig { candidates: CandidateSet::two_way(), ..Default::default() };
    let coord = Coordinator::new(cfg, 4);
    let fields = Dataset::Hurricane.generate(7, 0);
    let ours = coord.run(&fields, Policy::RateDistortion, 1e-4).unwrap().overall_ratio();
    let opt = coord.run(&fields, Policy::Optimum, 1e-4).unwrap().overall_ratio();
    assert!(opt >= ours * 0.95, "optimum {opt:.2} vs ours {ours:.2}");
}

#[test]
fn v2_partial_decode_is_independent_of_other_fields() {
    use adaptivec::coordinator::store::ContainerReader;

    // Write a chunked v2 container with >= 4 fields.
    let eb_rel = 1e-3;
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fields = Dataset::Atm.generate(7, 0);
    assert!(fields.len() >= 4);
    let report = coord.run_chunked(&fields, Policy::RateDistortion, eb_rel, 2048).unwrap();
    let bytes = report.to_container().to_bytes();

    // Learn every chunk's byte range from a pristine index, then
    // trash the payload bytes of every field *except* the target.
    // If `load_field` touched any other field's payload, the
    // corruption would surface.
    let pristine = ContainerReader::from_bytes(bytes.clone()).unwrap();
    let target = 2usize;
    let target_name = pristine.fields[target].name.clone();
    let mut corrupted = bytes.clone();
    let mut trashed = 0usize;
    for (fi, f) in pristine.fields.iter().enumerate() {
        if fi == target {
            continue;
        }
        for c in &f.chunks {
            for b in &mut corrupted[c.offset..c.offset + c.len] {
                *b = !*b;
                trashed += 1;
            }
        }
    }
    assert!(trashed > 0);

    let reader = ContainerReader::from_bytes(corrupted).unwrap();
    let got = coord.load_field(&reader, &target_name).unwrap();
    let orig = &fields[target];
    assert_eq!(got.name, orig.name);
    assert_eq!(got.dims, orig.dims);
    let vr = orig.value_range();
    let bound = if vr > 0.0 { eb_rel * vr } else { eb_rel };
    let stats = error_stats(&orig.data, &got.data);
    assert!(
        stats.max_abs_err <= bound * (1.0 + 1e-6),
        "partial decode broke the bound: {} > {bound}",
        stats.max_abs_err
    );

    // Sanity: the corruption is real (other fields' payload bytes all
    // changed, the target's were untouched) and irrelevant (the target
    // decodes bit-identically from pristine and corrupted containers).
    let from_pristine = coord.load_field(&pristine, &target_name).unwrap();
    assert_eq!(got.data, from_pristine.data);
}
