//! Property test for staged pipelines (DESIGN.md §15): every
//! registered pipeline — bare codecs and composed stage chains —
//! round-trips arbitrary 1D/2D/3D grids within the absolute error
//! bound, and lossless pipelines round-trip bit-exactly.

use adaptivec::codec_api::CodecRegistry;
use adaptivec::data::field::Dims;
use adaptivec::testing::proptest_lite::{forall, Gen};

/// Random grid: dimensionality, extents and data with a mix of smooth
/// structure, noise, exact zeros and sign flips (exercises the delta
/// stage's bit-pattern arithmetic and SZ's escape path).
fn grid_gen() -> Gen<(Dims, Vec<f32>)> {
    Gen::new(|r| {
        let dims = match r.below(3) {
            0 => Dims::D1(r.range(1, 400)),
            1 => Dims::D2(r.range(1, 24), r.range(1, 24)),
            _ => Dims::D3(r.range(1, 7), r.range(1, 9), r.range(1, 9)),
        };
        let base = r.range_f64(-100.0, 100.0);
        let slope = r.range_f64(-0.5, 0.5);
        let noise = r.range_f64(0.0, 5.0);
        let data: Vec<f32> = (0..dims.len())
            .map(|i| {
                if r.bool(0.02) {
                    0.0
                } else {
                    (base + slope * i as f64 + noise * r.gauss()) as f32
                }
            })
            .collect();
        (dims, data)
    })
}

#[test]
fn every_pipeline_roundtrips_within_bound_on_random_grids() {
    let registry = CodecRegistry::default();
    forall("pipeline roundtrip", 40, grid_gen(), |(dims, data)| {
        let vr = {
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &v in data {
                lo = lo.min(v as f64);
                hi = hi.max(v as f64);
            }
            (hi - lo).max(0.0)
        };
        let eb = (1e-3 * vr).max(1e-6);
        for (id, name) in registry.entries().collect::<Vec<_>>() {
            let p = registry.get(id).unwrap();
            let stream = match p.compress(data, *dims, eb) {
                Ok(s) => s,
                Err(e) => panic!("pipeline {name} failed to compress {dims:?}: {e}"),
            };
            let (recon, rdims) = match p.decompress(&stream) {
                Ok(x) => x,
                Err(e) => panic!("pipeline {name} failed to decompress {dims:?}: {e}"),
            };
            if recon.len() != data.len() {
                return false;
            }
            // Raw reports D1 by design (bare-bytes compatibility);
            // every other pipeline restores the true shape.
            if name != "raw" && rdims != *dims {
                return false;
            }
            if p.lossless() {
                if !data.iter().zip(&recon).all(|(a, b)| a.to_bits() == b.to_bits()) {
                    return false;
                }
            } else {
                let worst = data
                    .iter()
                    .zip(&recon)
                    .map(|(a, b)| (*a as f64 - *b as f64).abs())
                    .fold(0.0f64, f64::max);
                if worst > eb * (1.0 + 1e-6) {
                    eprintln!("pipeline {name} on {dims:?}: err {worst} > bound {eb}");
                    return false;
                }
            }
        }
        true
    });
}
