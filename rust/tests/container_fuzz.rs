//! Corruption fuzzing for both container wire formats: `from_bytes`
//! must return `Err` for malformed input — never panic, abort on a
//! huge attacker-controlled allocation, or read out of bounds.
//!
//! Three attack surfaces, per the v2 design (DESIGN.md §6):
//! truncation at every prefix length, bit flips in the index, and
//! out-of-range chunk offsets. Random-bytes parsing rides along via
//! `testing::proptest_lite`.

use adaptivec::baseline::Policy;
use adaptivec::codec_api::CodecRegistry;
use adaptivec::coordinator::store::{Container, ContainerReader};
use adaptivec::coordinator::Coordinator;
use adaptivec::data::atm;
use adaptivec::data::Field;
use adaptivec::estimator::selector::SelectorConfig;
use adaptivec::testing::proptest_lite::{forall, Gen};

fn fields(n: usize) -> Vec<Field> {
    (0..n).map(|i| atm::generate_field_scaled(99, i, 0)).collect()
}

/// A real v1 container produced by the coordinator.
fn v1_bytes() -> Vec<u8> {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let report = coord.run(&fields(2), Policy::RateDistortion, 1e-3).unwrap();
    report.to_container().to_bytes()
}

/// A real chunked v2 container produced by the coordinator.
fn v2_bytes() -> Vec<u8> {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let report = coord.run_chunked(&fields(2), Policy::RateDistortion, 1e-3, 2048).unwrap();
    report.to_container().to_bytes()
}

/// Parse attempts must never panic; Ok is fine (some corruptions are
/// silent at index level), Err is fine — so just drive the parser.
fn parse_both(bytes: &[u8]) {
    let _ = Container::from_bytes(bytes);
    let _ = ContainerReader::from_bytes(bytes.to_vec());
}

#[test]
fn truncation_at_every_prefix_is_an_error_v1() {
    let bytes = v1_bytes();
    for len in 0..bytes.len() {
        assert!(
            Container::from_bytes(&bytes[..len]).is_err(),
            "v1 prefix of {len}/{} bytes parsed",
            bytes.len()
        );
        assert!(
            ContainerReader::from_bytes(bytes[..len].to_vec()).is_err(),
            "v1 reader prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    assert!(Container::from_bytes(&bytes).is_ok());
}

#[test]
fn truncation_at_every_prefix_is_an_error_v2() {
    let bytes = v2_bytes();
    for len in 0..bytes.len() {
        assert!(
            ContainerReader::from_bytes(bytes[..len].to_vec()).is_err(),
            "v2 prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    let r = ContainerReader::from_bytes(bytes).unwrap();
    assert_eq!(r.version, 3);
}

#[test]
fn payload_bit_flips_always_caught_by_chunk_crc() {
    // CRC-32 detects every single-bit error, so flipping ANY payload
    // bit of a v3 container must surface as Err from the chunk that
    // owns it — seeded sweep over positions and bits.
    let registry = CodecRegistry::default();
    let bytes = v2_bytes();
    let reader = ContainerReader::from_bytes(bytes.clone()).unwrap();
    assert_eq!(reader.version, 3);
    let payload_start = reader.fields[0].chunks[0].offset;
    let payload_len: usize = reader.fields.iter().flat_map(|f| &f.chunks).map(|c| c.len).sum();
    let gen = Gen::<(usize, u8)>::new(move |r| (r.below(payload_len), (1u8) << r.below(8)));
    forall("every payload flip is caught", 60, gen, |&(pos, mask)| {
        let mut corrupt = bytes.clone();
        corrupt[payload_start + pos] ^= mask;
        let r = ContainerReader::from_bytes(corrupt).unwrap();
        // Find the chunk owning the flipped byte; its decode must err.
        for (fi, f) in r.fields.iter().enumerate() {
            for (ci, c) in f.chunks.iter().enumerate() {
                let abs = payload_start + pos;
                if abs >= c.offset && abs < c.offset + c.len {
                    return r.chunk_bytes(fi, ci).is_err()
                        && r.decode_chunk(&registry, fi, ci).is_err();
                }
            }
        }
        false // flipped byte must belong to some chunk
    });
}

#[test]
fn single_bit_flips_in_header_and_index_never_panic() {
    for bytes in [v1_bytes(), v2_bytes()] {
        // Flip every bit of the first KiB — for these containers that
        // covers magic, counts, names, dims, selection bytes, offsets
        // and lengths, plus the head of the payload region.
        let span = bytes.len().min(1024);
        for pos in 0..span {
            for bit in 0..8 {
                let mut c = bytes.clone();
                c[pos] ^= 1 << bit;
                // Parse must be total: Ok or Err, never a panic/abort.
                parse_both(&c);
            }
        }
    }
}

#[test]
fn corrupt_selection_bytes_rejected_at_decode() {
    // Flipping a chunk's selection byte to an unregistered id must
    // surface as Err from the registry, not a panic.
    let registry = CodecRegistry::default();
    let reader = ContainerReader::from_bytes(v2_bytes()).unwrap();
    for (fi, f) in reader.fields.iter().enumerate() {
        for ci in 0..f.chunks.len() {
            let mut bad = reader.clone();
            bad.fields[fi].chunks[ci].selection = 0xEE;
            assert!(bad.decode_chunk(&registry, fi, ci).is_err());
        }
    }
}

#[test]
fn random_garbage_never_panics() {
    // Random byte soup, with and without a valid magic prefix.
    let gen = Gen::<Vec<u8>>::new(|r| {
        let n = r.range(0, 512);
        let mut v: Vec<u8> = (0..n).map(|_| r.below(256) as u8).collect();
        let magic: Option<&[u8; 8]> = match r.below(4) {
            0 => Some(b"ADAPTC01"),
            1 => Some(b"ADAPTC02"),
            2 => Some(b"ADAPTC03"),
            _ => None,
        };
        if let Some(magic) = magic {
            for (i, b) in magic.iter().enumerate() {
                if i < v.len() {
                    v[i] = *b;
                }
            }
        }
        v
    });
    forall("container parsing never panics", 500, gen, |bytes| {
        parse_both(bytes);
        true
    });
}

#[test]
fn truncation_points_fuzzed() {
    // proptest_lite-driven truncation + flip combos on the v2 format:
    // pure truncation must parse as Err; an extra bit flip could in
    // principle re-align the framing, so there the bar is "no panic".
    let bytes = v2_bytes();
    let n = bytes.len();
    let gen =
        Gen::<(usize, usize, bool)>::new(move |r| (r.range(0, n), r.range(0, n * 8), r.bool(0.5)));
    forall("v2 truncate(+flip) never panics", 300, gen, |&(cut, flip_bit, flip)| {
        let mut c = bytes[..cut].to_vec();
        if flip && !c.is_empty() {
            let pos = (flip_bit / 8) % c.len();
            c[pos] ^= 1 << (flip_bit % 8);
            parse_both(&c);
            true
        } else {
            ContainerReader::from_bytes(c).is_err()
        }
    });
}

/// A real chunked container whose chunks select composed pipelines
/// (staged selection bytes ≥ FIRST_PIPELINE_ID, DESIGN.md §15).
fn v2_pipeline_bytes() -> Vec<u8> {
    use adaptivec::estimator::selector::CandidateSet;
    let cfg = SelectorConfig {
        candidates: CandidateSet::parse("bitround+sz,delta+arith").unwrap(),
        ..SelectorConfig::default()
    };
    let coord = Coordinator::new(cfg, 2);
    let report = coord.run_chunked(&fields(2), Policy::RateDistortion, 1e-3, 2048).unwrap();
    report.to_container().to_bytes()
}

#[test]
fn unknown_pipeline_selection_bytes_rejected_at_decode() {
    // Ids just past the registered pipeline range, and far past it,
    // must surface as Err from the registry — never a panic or a
    // misrouted decode through a neighboring pipeline.
    use adaptivec::codec_api::FIRST_PIPELINE_ID;
    let registry = CodecRegistry::default();
    let reader = ContainerReader::from_bytes(v2_pipeline_bytes()).unwrap();
    let max_registered = (0u8..=255).filter(|&id| registry.lookup(id).is_some()).max().unwrap();
    assert!(max_registered >= FIRST_PIPELINE_ID, "pipeline run registered no pipelines");
    for bad in [max_registered + 1, 63, 200, 0xEE] {
        for (fi, f) in reader.fields.iter().enumerate() {
            for ci in 0..f.chunks.len() {
                let mut r = reader.clone();
                r.fields[fi].chunks[ci].selection = bad;
                assert!(
                    r.decode_chunk(&registry, fi, ci).is_err(),
                    "selection byte {bad} decoded"
                );
            }
        }
    }
}

#[test]
fn truncated_pipeline_stage_configs_error_never_panic() {
    // Composed pipeline streams lead with varint-framed stage config
    // blobs; cutting the stream anywhere inside them (or anywhere at
    // all) must decode as Err, never a panic or wild allocation.
    use adaptivec::codec_api::FIRST_PIPELINE_ID;
    let registry = CodecRegistry::default();
    let reader = ContainerReader::from_bytes(v2_pipeline_bytes()).unwrap();
    let mut pipeline_chunks = 0usize;
    for (fi, f) in reader.fields.iter().enumerate() {
        for (ci, c) in f.chunks.iter().enumerate() {
            if c.selection < FIRST_PIPELINE_ID {
                continue;
            }
            pipeline_chunks += 1;
            let bytes = reader.chunk_bytes(fi, ci).unwrap();
            // Every prefix that clips the stream proper must error.
            for cut in [0usize, 1, 2, bytes.len() / 2, bytes.len().saturating_sub(1)] {
                let _ = registry.decode_stream(c.selection, &bytes[..cut.min(bytes.len())]);
            }
            assert!(registry.decode_stream(c.selection, &[]).is_err());
            assert!(registry.decode_stream(c.selection, &bytes[..1.min(bytes.len())]).is_err());
            // The untruncated stream still decodes.
            registry.decode_stream(c.selection, &bytes).unwrap();
        }
    }
    assert!(pipeline_chunks > 0, "no pipeline-selected chunks to fuzz");
}

#[test]
fn pipeline_streams_random_flips_never_panic() {
    // Random single-byte corruption anywhere in a composed pipeline
    // stream: decode must be total (Ok or Err), with CRC off the table
    // because we feed the registry directly.
    use adaptivec::codec_api::FIRST_PIPELINE_ID;
    let registry = CodecRegistry::default();
    let reader = ContainerReader::from_bytes(v2_pipeline_bytes()).unwrap();
    let mut streams: Vec<(u8, Vec<u8>)> = Vec::new();
    for (fi, f) in reader.fields.iter().enumerate() {
        for (ci, c) in f.chunks.iter().enumerate() {
            if c.selection >= FIRST_PIPELINE_ID {
                streams.push((c.selection, reader.chunk_bytes(fi, ci).unwrap()));
            }
        }
    }
    assert!(!streams.is_empty());
    let n = streams.len();
    let gen = Gen::<(usize, usize, u8)>::new(move |r| {
        (r.below(n), r.below(1 << 20), (1u8) << r.below(8))
    });
    forall("pipeline stream flips never panic", 200, gen, |&(si, pos, mask)| {
        let (sel, stream) = &streams[si];
        let mut bad = stream.clone();
        let p = pos % bad.len();
        bad[p] ^= mask;
        let _ = registry.decode_stream(*sel, &bad);
        true
    });
}
