//! Corruption fuzzing for both container wire formats: `from_bytes`
//! must return `Err` for malformed input — never panic, abort on a
//! huge attacker-controlled allocation, or read out of bounds.
//!
//! Three attack surfaces, per the v2 design (DESIGN.md §6):
//! truncation at every prefix length, bit flips in the index, and
//! out-of-range chunk offsets. Random-bytes parsing rides along via
//! `testing::proptest_lite`.

use adaptivec::baseline::Policy;
use adaptivec::codec_api::CodecRegistry;
use adaptivec::coordinator::store::{Container, ContainerReader};
use adaptivec::coordinator::Coordinator;
use adaptivec::data::atm;
use adaptivec::data::Field;
use adaptivec::estimator::selector::SelectorConfig;
use adaptivec::testing::proptest_lite::{forall, Gen};

fn fields(n: usize) -> Vec<Field> {
    (0..n).map(|i| atm::generate_field_scaled(99, i, 0)).collect()
}

/// A real v1 container produced by the coordinator.
fn v1_bytes() -> Vec<u8> {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let report = coord.run(&fields(2), Policy::RateDistortion, 1e-3).unwrap();
    report.to_container().to_bytes()
}

/// A real chunked v2 container produced by the coordinator.
fn v2_bytes() -> Vec<u8> {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let report = coord.run_chunked(&fields(2), Policy::RateDistortion, 1e-3, 2048).unwrap();
    report.to_container().to_bytes()
}

/// Parse attempts must never panic; Ok is fine (some corruptions are
/// silent at index level), Err is fine — so just drive the parser.
fn parse_both(bytes: &[u8]) {
    let _ = Container::from_bytes(bytes);
    let _ = ContainerReader::from_bytes(bytes.to_vec());
}

#[test]
fn truncation_at_every_prefix_is_an_error_v1() {
    let bytes = v1_bytes();
    for len in 0..bytes.len() {
        assert!(
            Container::from_bytes(&bytes[..len]).is_err(),
            "v1 prefix of {len}/{} bytes parsed",
            bytes.len()
        );
        assert!(
            ContainerReader::from_bytes(bytes[..len].to_vec()).is_err(),
            "v1 reader prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    assert!(Container::from_bytes(&bytes).is_ok());
}

#[test]
fn truncation_at_every_prefix_is_an_error_v2() {
    let bytes = v2_bytes();
    for len in 0..bytes.len() {
        assert!(
            ContainerReader::from_bytes(bytes[..len].to_vec()).is_err(),
            "v2 prefix of {len}/{} bytes parsed",
            bytes.len()
        );
    }
    let r = ContainerReader::from_bytes(bytes).unwrap();
    assert_eq!(r.version, 3);
}

#[test]
fn payload_bit_flips_always_caught_by_chunk_crc() {
    // CRC-32 detects every single-bit error, so flipping ANY payload
    // bit of a v3 container must surface as Err from the chunk that
    // owns it — seeded sweep over positions and bits.
    let registry = CodecRegistry::default();
    let bytes = v2_bytes();
    let reader = ContainerReader::from_bytes(bytes.clone()).unwrap();
    assert_eq!(reader.version, 3);
    let payload_start = reader.fields[0].chunks[0].offset;
    let payload_len: usize = reader.fields.iter().flat_map(|f| &f.chunks).map(|c| c.len).sum();
    let gen = Gen::<(usize, u8)>::new(move |r| (r.below(payload_len), (1u8) << r.below(8)));
    forall("every payload flip is caught", 60, gen, |&(pos, mask)| {
        let mut corrupt = bytes.clone();
        corrupt[payload_start + pos] ^= mask;
        let r = ContainerReader::from_bytes(corrupt).unwrap();
        // Find the chunk owning the flipped byte; its decode must err.
        for (fi, f) in r.fields.iter().enumerate() {
            for (ci, c) in f.chunks.iter().enumerate() {
                let abs = payload_start + pos;
                if abs >= c.offset && abs < c.offset + c.len {
                    return r.chunk_bytes(fi, ci).is_err()
                        && r.decode_chunk(&registry, fi, ci).is_err();
                }
            }
        }
        false // flipped byte must belong to some chunk
    });
}

#[test]
fn single_bit_flips_in_header_and_index_never_panic() {
    for bytes in [v1_bytes(), v2_bytes()] {
        // Flip every bit of the first KiB — for these containers that
        // covers magic, counts, names, dims, selection bytes, offsets
        // and lengths, plus the head of the payload region.
        let span = bytes.len().min(1024);
        for pos in 0..span {
            for bit in 0..8 {
                let mut c = bytes.clone();
                c[pos] ^= 1 << bit;
                // Parse must be total: Ok or Err, never a panic/abort.
                parse_both(&c);
            }
        }
    }
}

#[test]
fn corrupt_selection_bytes_rejected_at_decode() {
    // Flipping a chunk's selection byte to an unregistered id must
    // surface as Err from the registry, not a panic.
    let registry = CodecRegistry::default();
    let reader = ContainerReader::from_bytes(v2_bytes()).unwrap();
    for (fi, f) in reader.fields.iter().enumerate() {
        for ci in 0..f.chunks.len() {
            let mut bad = reader.clone();
            bad.fields[fi].chunks[ci].selection = 0xEE;
            assert!(bad.decode_chunk(&registry, fi, ci).is_err());
        }
    }
}

#[test]
fn random_garbage_never_panics() {
    // Random byte soup, with and without a valid magic prefix.
    let gen = Gen::<Vec<u8>>::new(|r| {
        let n = r.range(0, 512);
        let mut v: Vec<u8> = (0..n).map(|_| r.below(256) as u8).collect();
        let magic: Option<&[u8; 8]> = match r.below(4) {
            0 => Some(b"ADAPTC01"),
            1 => Some(b"ADAPTC02"),
            2 => Some(b"ADAPTC03"),
            _ => None,
        };
        if let Some(magic) = magic {
            for (i, b) in magic.iter().enumerate() {
                if i < v.len() {
                    v[i] = *b;
                }
            }
        }
        v
    });
    forall("container parsing never panics", 500, gen, |bytes| {
        parse_both(bytes);
        true
    });
}

#[test]
fn truncation_points_fuzzed() {
    // proptest_lite-driven truncation + flip combos on the v2 format:
    // pure truncation must parse as Err; an extra bit flip could in
    // principle re-align the framing, so there the bar is "no panic".
    let bytes = v2_bytes();
    let n = bytes.len();
    let gen =
        Gen::<(usize, usize, bool)>::new(move |r| (r.range(0, n), r.range(0, n * 8), r.bool(0.5)));
    forall("v2 truncate(+flip) never panics", 300, gen, |&(cut, flip_bit, flip)| {
        let mut c = bytes[..cut].to_vec();
        if flip && !c.is_empty() {
            let pos = (flip_bit / 8) % c.len();
            c[pos] ^= 1 << (flip_bit % 8);
            parse_both(&c);
            true
        } else {
            ContainerReader::from_bytes(c).is_err()
        }
    });
}
