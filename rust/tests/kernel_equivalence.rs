//! Differential property tests for the hardware-speed hot paths
//! (DESIGN.md §13): the batched/SIMD kernels must be **bit-identical**
//! to the scalar per-point reference forms — same symbols, same
//! literals, same reconstructions — across 1D/2D/3D layouts and
//! adversarial float inputs (±0.0, denormals, huge magnitudes).
//!
//! The compressed stream encodes symbols + literals verbatim, so
//! byte-equality of `compress` vs `compress_reference` proves the
//! batched codec loop emits identical symbol and literal streams;
//! bit-equality of the decompressed fields proves the reconstructions
//! match point-for-point.

use adaptivec::data::field::Dims;
use adaptivec::sz::kernels;
use adaptivec::sz::lorenzo;
use adaptivec::sz::SzCompressor;
use adaptivec::testing::proptest_lite::{forall, forall_vec_f32, Gen};
use adaptivec::testing::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Wide-dynamic-range values salted with the denormal / signed-zero /
/// near-overflow specials where evaluation order becomes observable.
fn salt_specials(mut v: Vec<f32>) -> Vec<f32> {
    const SPECIALS: [f32; 10] = [
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-42,
        -1e-42,
        3.4e38,
        -3.4e38,
        1e-30,
        -1e-30,
    ];
    for (i, x) in v.iter_mut().enumerate() {
        if i % 5 == 0 {
            *x = SPECIALS[(i / 5) % SPECIALS.len()];
        }
    }
    v
}

/// Factor `n` into a (ny, nx) grid that is not degenerate when
/// possible, so 2D runs exercise real row boundaries.
fn grid_2d(n: usize) -> (usize, usize) {
    for nx in (2..=n).rev() {
        if n % nx == 0 && n / nx >= 2 {
            return (n / nx, nx);
        }
    }
    (1, n)
}

fn grid_3d(n: usize) -> Option<(usize, usize, usize)> {
    for nz in 2..=n {
        if n % nz != 0 {
            continue;
        }
        let rest = n / nz;
        let (ny, nx) = grid_2d(rest);
        if ny >= 2 && nx >= 2 {
            return Some((nz, ny, nx));
        }
    }
    None
}

/// Compress + decompress through both the batched and the reference
/// paths and assert full bit-identity of streams and reconstructions.
fn assert_paths_identical(data: &[f32], dims: Dims, eb: f64) {
    let sz = SzCompressor::default();
    let fast = sz.compress(data, dims, eb).unwrap();
    let refr = sz.compress_reference(data, dims, eb).unwrap();
    assert_eq!(fast, refr, "compressed stream differs for {dims:?} eb={eb}");

    let (rec_fast, d1) = sz.decompress(&fast).unwrap();
    let (rec_ref, d2) = sz.decompress_reference(&fast).unwrap();
    assert_eq!(d1, dims);
    assert_eq!(d2, dims);
    assert_eq!(bits(&rec_fast), bits(&rec_ref), "reconstruction differs for {dims:?}");

    // And the bound still holds (sanity on top of equivalence).
    for (&a, &b) in data.iter().zip(&rec_fast) {
        assert!(
            (a as f64 - b as f64).abs() <= eb * (1.0 + 1e-9),
            "bound violated: {a} vs {b} (eb {eb})"
        );
    }
}

#[test]
fn prop_codec_paths_bit_identical_1d() {
    forall_vec_f32(
        "kernels codec 1d bit-identity",
        30,
        Gen::vec_f32_wide(1..600),
        |v| {
            let v = salt_specials(v.to_vec());
            for eb in [1e-3, 1e-7, 10.0] {
                assert_paths_identical(&v, Dims::D1(v.len()), eb);
            }
            true
        },
    );
}

#[test]
fn prop_codec_paths_bit_identical_2d() {
    forall_vec_f32(
        "kernels codec 2d bit-identity",
        25,
        Gen::vec_f32_wide(4..600),
        |v| {
            let v = salt_specials(v.to_vec());
            let (ny, nx) = grid_2d(v.len());
            for eb in [1e-3, 1e-7] {
                assert_paths_identical(&v[..ny * nx], Dims::D2(ny, nx), eb);
            }
            true
        },
    );
}

#[test]
fn prop_codec_paths_bit_identical_3d() {
    forall_vec_f32(
        "kernels codec 3d bit-identity",
        25,
        Gen::vec_f32_wide(8..600),
        |v| {
            let v = salt_specials(v.to_vec());
            if let Some((nz, ny, nx)) = grid_3d(v.len()) {
                for eb in [1e-3, 1e-7] {
                    assert_paths_identical(&v[..nz * ny * nx], Dims::D3(nz, ny, nx), eb);
                }
            }
            true
        },
    );
}

#[test]
fn prop_smooth_fields_bit_identical() {
    // Smooth inputs drive the quantized (non-escape) path almost
    // everywhere — the opposite regime from the wide generator.
    forall_vec_f32(
        "kernels codec smooth bit-identity",
        15,
        Gen::vec_f32_smooth(64..900, 50.0),
        |v| {
            let (ny, nx) = grid_2d(v.len());
            assert_paths_identical(&v[..ny * nx], Dims::D2(ny, nx), 1e-3);
            assert_paths_identical(v, Dims::D1(v.len()), 1e-4);
            true
        },
    );
}

#[test]
fn prop_row_error_kernels_bit_identical() {
    // Direct SIMD-vs-scalar comparison of the prediction-error kernels
    // at every row width (tail handling) with special-salted inputs.
    forall(
        "row_errors simd vs scalar",
        40,
        Gen::usize(1..200),
        |&n| {
            let mut rng = Rng::new(0xBEEF ^ n as u64);
            let gen_row = |rng: &mut Rng| {
                salt_specials((0..n).map(|_| rng.range_f64(-1e7, 1e7) as f32).collect())
            };
            let row = gen_row(&mut rng);
            let prev = gen_row(&mut rng);
            let zm1 = gen_row(&mut rng);
            let zym1 = gen_row(&mut rng);
            let mut fast = vec![0.0f32; n];
            let mut refr = vec![0.0f32; n];

            kernels::row_errors_1d(&row, &mut fast);
            kernels::row_errors_1d_scalar(&row, &mut refr);
            if bits(&fast) != bits(&refr) {
                return false;
            }

            kernels::row_errors_2d(&row, &prev, &mut fast);
            kernels::row_errors_2d_scalar(&row, &prev, &mut refr);
            if bits(&fast) != bits(&refr) {
                return false;
            }

            kernels::row_errors_3d(&row, &prev, &zm1, &zym1, &mut fast);
            kernels::row_errors_3d_scalar(&row, &prev, &zm1, &zym1, &mut refr);
            bits(&fast) == bits(&refr)
        },
    );
}

#[test]
fn prop_full_field_errors_match_per_point() {
    // The batched full-field transform must equal the per-point
    // original-neighbor reference at every index, for every dim shape.
    forall_vec_f32(
        "prediction_errors_full vs original",
        25,
        Gen::vec_f32_wide(8..500),
        |v| {
            let v = salt_specials(v.to_vec());
            let mut shapes = vec![Dims::D1(v.len())];
            let (ny, nx) = grid_2d(v.len());
            shapes.push(Dims::D2(ny, nx));
            if let Some((nz, ny, nx)) = grid_3d(v.len()) {
                shapes.push(Dims::D3(nz, ny, nx));
            }
            for dims in shapes {
                let n = dims.len();
                let idx: Vec<usize> = (0..n).collect();
                let batched = lorenzo::prediction_errors_full(&v[..n], dims);
                let reference = lorenzo::prediction_errors_original(&v[..n], dims, &idx);
                if bits(&batched) != bits(&reference) {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn denormal_heavy_field_roundtrips_identically() {
    // A field made *entirely* of denormals and signed zeros: the
    // quantizer sees errors far below delta, so everything lands in
    // the zero bin — both paths must still agree bit-for-bit.
    let v: Vec<f32> = (0..257)
        .map(|i| match i % 4 {
            0 => f32::MIN_POSITIVE * (i as f32),
            1 => -1e-42,
            2 => -0.0,
            _ => 1e-44,
        })
        .collect();
    assert_paths_identical(&v, Dims::D1(257), 1e-3);
    assert_paths_identical(&v[..256], Dims::D2(16, 16), 1e-3);
    assert_paths_identical(&v[..252], Dims::D3(7, 6, 6), 1e-3);
}

#[test]
fn escape_heavy_field_roundtrips_identically() {
    // Huge white noise against a tiny bound: nearly every point escapes
    // to a literal, exercising the literal stream ordering end-to-end.
    let mut rng = Rng::new(0xD1FF);
    let v: Vec<f32> = (0..360).map(|_| rng.range_f64(-1e8, 1e8) as f32).collect();
    assert_paths_identical(&v, Dims::D1(360), 1e-9);
    assert_paths_identical(&v, Dims::D2(18, 20), 1e-9);
    assert_paths_identical(&v, Dims::D3(6, 6, 10), 1e-9);
}

#[test]
fn kernel_dispatch_reports_a_backend() {
    // The active kernel is an env-pinned process-wide choice; whichever
    // it is, the equivalence suite above proves it safe.
    assert!(matches!(kernels::active_kernel(), "avx2" | "sse2" | "scalar"));
}
