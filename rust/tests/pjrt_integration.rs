//! Integration: the AOT (JAX/Pallas → HLO text → PJRT) Stage-I engine
//! agrees with the native Rust Stage-I on real estimator inputs —
//! proving the three-layer architecture composes end to end.
//!
//! Skips (with a message) when `make artifacts` has not run, and is
//! compiled out entirely unless both the `pjrt` feature and the
//! `pjrt_xla` cfg are active (stub-path builds link an engine that can
//! never produce results to compare — DESIGN.md §10).
#![cfg(all(feature = "pjrt", pjrt_xla))]

use adaptivec::data::atm;
use adaptivec::estimator::sampling;
use adaptivec::runtime::{default_artifacts_dir, PjrtEngine};
use adaptivec::sz::lorenzo;
use adaptivec::zfp::block;
use adaptivec::zfp::transform::{t_zfp, ParametricBot};

fn engine() -> Option<PjrtEngine> {
    let dir = default_artifacts_dir();
    if !dir.join("bot2d.hlo.txt").is_file() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::load_dir(dir).expect("engine"))
}

#[test]
fn stage1_bot_agrees_on_real_samples() {
    let Some(eng) = engine() else { return };
    let f = atm::generate_field(11, 0);
    let sample = sampling::sample_blocks(f.dims, 0.05);
    let mut blocks = Vec::with_capacity(sample.blocks.len() * 16);
    let mut blk = [0.0f32; 16];
    for &c in &sample.blocks {
        block::gather(&f.data, f.dims, c, &mut blk);
        blocks.extend_from_slice(&blk);
    }
    let pjrt = eng.bot_forward_2d(&blocks).unwrap();
    let bot = ParametricBot::new(t_zfp());
    let scale = f.value_range();
    for (b, chunk) in blocks.chunks_exact(16).enumerate() {
        let mut native: Vec<f64> = chunk.iter().map(|&v| v as f64).collect();
        bot.forward(&mut native, 2);
        for (p, n) in pjrt[b * 16..(b + 1) * 16].iter().zip(&native) {
            assert!(
                (*p as f64 - n).abs() <= 1e-5 * scale.max(1.0),
                "block {b}: {p} vs {n}"
            );
        }
    }
}

#[test]
fn stage1_lorenzo_agrees_on_real_samples() {
    let Some(eng) = engine() else { return };
    let f = atm::generate_field(11, 2);
    let sample = sampling::sample_blocks(f.dims, 0.05);
    let idx = sample.point_indices();
    let native = lorenzo::prediction_errors_original(&f.data, f.dims, &idx);

    // Gather neighbor arrays exactly as the PJRT path expects.
    let nx = match f.dims {
        adaptivec::data::field::Dims::D2(_, nx) => nx,
        _ => unreachable!(),
    };
    let at = |i: isize| -> f32 {
        if i < 0 {
            0.0
        } else {
            f.data[i as usize]
        }
    };
    let mut x = Vec::new();
    let mut l = Vec::new();
    let mut u = Vec::new();
    let mut d = Vec::new();
    for &i in &idx {
        let (y, xx) = (i / nx, i % nx);
        x.push(f.data[i]);
        l.push(if xx >= 1 { at(i as isize - 1) } else { 0.0 });
        u.push(if y >= 1 { at(i as isize - nx as isize) } else { 0.0 });
        d.push(if xx >= 1 && y >= 1 { at(i as isize - nx as isize - 1) } else { 0.0 });
    }
    let pjrt = eng.lorenzo_2d(&x, &l, &u, &d).unwrap();
    for (i, (p, n)) in pjrt.iter().zip(&native).enumerate() {
        assert!((p - n).abs() <= 1e-5 * n.abs().max(1e-3), "sample {i}: {p} vs {n}");
    }
}

#[test]
fn nsb_hist_consistent_with_native_histogram() {
    let Some(eng) = engine() else { return };
    let f = atm::generate_field(11, 1);
    let sample = sampling::sample_blocks(f.dims, 0.05);
    let mut blocks = Vec::with_capacity(sample.blocks.len() * 16);
    let mut blk = [0.0f32; 16];
    for &c in &sample.blocks {
        block::gather(&f.data, f.dims, c, &mut blk);
        blocks.extend_from_slice(&blk);
    }
    let inv_delta = 10.0f32 / f.value_range() as f32;
    let (nsb, hist) = eng.nsb_hist_2d(&blocks, inv_delta).unwrap();
    assert_eq!(nsb.len(), blocks.len() / 16);
    // Native recomputation of the histogram (transform + quantize).
    let bot = ParametricBot::new(t_zfp());
    let mut native_hist = vec![0.0f32; 64];
    for chunk in blocks.chunks_exact(16) {
        let mut d: Vec<f64> = chunk.iter().map(|&v| v as f64).collect();
        bot.forward(&mut d, 2);
        for &c in &d {
            let q = (c * inv_delta as f64).round().clamp(-32.0, 31.0) + 32.0;
            native_hist[q as usize] += 1.0;
        }
    }
    // PJRT histogram includes zero-padding of the last batch in the
    // center bin (rank 32); all other bins must match exactly.
    for (i, (p, n)) in hist.iter().zip(&native_hist).enumerate() {
        if i == 32 {
            assert!(p >= n, "center bin loses mass: {p} vs {n}");
        } else {
            assert_eq!(*p, *n, "bin {i}");
        }
    }
}
