//! Multiway-vs-exhaustive selection accuracy (the §6.2 accuracy
//! protocol extended to three candidates): on the synthetic corpus,
//! the three-way estimator's pick must be the codec whose *real*
//! compressed output at its iso-PSNR operating point is the smallest,
//! with near-ties (within 10% of the best size) not counted as misses
//! — misselection among near-equal candidates costs almost nothing
//! (the paper's "wrong picks cost ≤ 3.3%" observation).

use adaptivec::codec_api::Choice;
use adaptivec::data::Dataset;
use adaptivec::estimator::selector::AutoSelector;

const CANDIDATES: [Choice; 3] = [Choice::Sz, Choice::Zfp, Choice::Dct];

#[test]
fn three_way_pick_matches_exhaustive_size_ranking() {
    let sel = AutoSelector::default();
    let mut total = 0usize;
    let mut correct = 0usize;
    let mut picked_bytes = 0u64;
    let mut best_bytes = 0u64;
    for ds in Dataset::ALL {
        for f in ds.generate(2018, 0) {
            let vr = f.value_range();
            if vr <= 0.0 {
                continue;
            }
            for eb_rel in [1e-3, 1e-4] {
                let eb = eb_rel * vr;
                let (pick, est) = sel.select_abs(&f, eb, vr).unwrap();
                // Exhaustive ground truth: run every candidate at the
                // operating point the estimator modeled for it.
                let sizes: Vec<(Choice, usize)> = CANDIDATES
                    .into_iter()
                    .map(|c| {
                        let bound = est.bound_for(c).max(f64::MIN_POSITIVE);
                        (c, sel.compress_forced(&f, bound, c).unwrap().len())
                    })
                    .collect();
                let best = sizes.iter().map(|&(_, s)| s).min().unwrap();
                let picked = sizes.iter().find(|&&(c, _)| c == pick).unwrap().1;
                total += 1;
                picked_bytes += picked as u64;
                best_bytes += best as u64;
                if picked as f64 <= best as f64 * 1.10 {
                    correct += 1;
                }
            }
        }
    }
    assert!(total >= 20, "corpus unexpectedly small: {total}");
    let acc = correct as f64 / total as f64;
    assert!(
        acc >= 0.90,
        "three-way selection accuracy {acc:.3} ({correct}/{total}) below 90%"
    );
    // Aggregate cost of every misselection stays small: the picked
    // outputs together are within 10% of the exhaustive optimum.
    assert!(
        (picked_bytes as f64) <= best_bytes as f64 * 1.10,
        "picked {picked_bytes} vs exhaustive best {best_bytes}"
    );
}
