//! Differential proof for the staged-pipeline refactor (DESIGN.md
//! §15): the default pipeline set must reproduce the flat registry's
//! outputs **byte-identically**. Two layers of evidence:
//!
//! 1. Stream level — `CodecRegistry::encode` for every bare codec id
//!    equals the selection byte + the codec's direct `compress` output
//!    across fields and bounds (the single-stage fast path adds zero
//!    header bytes).
//! 2. Container level — chunked containers written under the default
//!    candidate set carry only bare-codec selection bytes, and every
//!    chunk payload decodes through the **direct** compressor,
//!    bypassing the pipeline layer entirely. A pipeline wire header
//!    would break that decode, so this pins the format, not just the
//!    values.

use adaptivec::baseline::Policy;
use adaptivec::codec_api::{Choice, Codec, CodecRegistry, RawCodec, FIRST_PIPELINE_ID};
use adaptivec::coordinator::store::ContainerReader;
use adaptivec::coordinator::Coordinator;
use adaptivec::data::{atm, Field};
use adaptivec::dct::{DctCompressor, DctConfig};
use adaptivec::estimator::selector::SelectorConfig;
use adaptivec::sz::{SzCompressor, SzConfig};
use adaptivec::zfp::{ZfpCompressor, ZfpConfig};

fn fields() -> Vec<Field> {
    // One field per data class: Smooth, Fraction, Rough.
    [0usize, 4, 7].iter().map(|&i| atm::generate_field_scaled(2018, i, 0)).collect()
}

/// The pre-refactor flat path: direct compressor dispatch, no
/// pipeline layer.
fn flat_compress(choice: Choice, data: &[f32], dims: adaptivec::data::field::Dims, eb: f64) -> Vec<u8> {
    match choice {
        Choice::Sz => SzCompressor::new(SzConfig::default()).compress(data, dims, eb).unwrap(),
        Choice::Zfp => ZfpCompressor::new(ZfpConfig::default()).compress(data, dims, eb).unwrap(),
        Choice::Dct => DctCompressor::new(DctConfig::default()).compress(data, dims, eb).unwrap(),
        _ => RawCodec.compress(data, dims, eb).unwrap(),
    }
}

#[test]
fn registry_streams_match_flat_path_across_fields_and_bounds() {
    let registry = CodecRegistry::default();
    for f in fields() {
        let vr = f.value_range();
        for eb_rel in [1e-3, 1e-4] {
            let eb = eb_rel * vr;
            for choice in Choice::ALL {
                let flat = flat_compress(choice, &f.data, f.dims, eb);
                let framed = registry.encode(choice, &f.data, f.dims, eb).unwrap();
                assert_eq!(framed[0], choice.id());
                assert_eq!(
                    &framed[1..],
                    flat.as_slice(),
                    "{choice:?} at {eb_rel:e} on {}",
                    f.name
                );
            }
        }
    }
}

#[test]
fn default_chunked_containers_carry_flat_registry_streams() {
    // Default candidate set (no pipelines): for every policy and
    // chunking, each chunk must be a bare-codec stream that the direct
    // compressor can decode without going through the pipeline layer.
    let registry = CodecRegistry::default();
    let fields = fields();
    for policy in [Policy::RateDistortion, Policy::AlwaysSz, Policy::AlwaysZfp] {
        for chunk_elems in [2048usize, 100_000] {
            let coord = Coordinator::new(SelectorConfig::default(), 2);
            let report = coord.run_chunked(&fields, policy, 1e-3, chunk_elems).unwrap();
            let reader =
                ContainerReader::from_bytes(report.to_container().to_bytes()).unwrap();
            for (fi, fld) in reader.fields.iter().enumerate() {
                for (ci, c) in fld.chunks.iter().enumerate() {
                    assert!(
                        c.selection < FIRST_PIPELINE_ID,
                        "{policy:?}: default run selected pipeline id {}",
                        c.selection
                    );
                    let bytes = reader.chunk_bytes(fi, ci).unwrap();
                    let via_registry = registry.decode_stream(c.selection, &bytes).unwrap();
                    // Bypass the registry entirely: the stream must be
                    // a plain codec stream, so the direct decompressor
                    // accepts it byte-for-byte.
                    let direct = match Choice::from_id(c.selection).unwrap() {
                        Choice::Sz => SzCompressor::default().decompress(&bytes).unwrap(),
                        Choice::Zfp => {
                            ZfpCompressor::new(ZfpConfig::default()).decompress(&bytes).unwrap()
                        }
                        Choice::Dct => {
                            DctCompressor::new(DctConfig::default()).decompress(&bytes).unwrap()
                        }
                        _ => RawCodec.decompress(&bytes).unwrap(),
                    };
                    let same_bits = via_registry
                        .0
                        .iter()
                        .zip(&direct.0)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(
                        same_bits && via_registry.0.len() == direct.0.len(),
                        "{policy:?} chunk ({fi},{ci}) decodes differently"
                    );
                }
            }
        }
    }
}

#[test]
fn enabling_pipelines_leaves_bare_codec_streams_unchanged() {
    // The estimator may *select* differently once pipelines compete,
    // but any chunk that still selects a bare codec must produce the
    // exact bytes the flat path produced.
    use adaptivec::estimator::selector::{CandidateSet, PipelineMask};
    let cfg = SelectorConfig {
        candidates: CandidateSet { pipelines: PipelineMask::builtins(), ..CandidateSet::all() },
        ..SelectorConfig::default()
    };
    let coord = Coordinator::new(cfg, 2);
    let fields = fields();
    let report = coord.run_chunked(&fields, Policy::RateDistortion, 1e-3, 2048).unwrap();
    let reader = ContainerReader::from_bytes(report.to_container().to_bytes()).unwrap();
    let registry = CodecRegistry::default();
    for (fi, fld) in reader.fields.iter().enumerate() {
        for (ci, c) in fld.chunks.iter().enumerate() {
            let bytes = reader.chunk_bytes(fi, ci).unwrap();
            // Every chunk decodes through the registry.
            registry.decode_stream(c.selection, &bytes).unwrap();
            // Bare-codec chunks remain flat streams even when
            // pipelines competed for the selection.
            if c.selection == Choice::Sz.id() {
                SzCompressor::default().decompress(&bytes).unwrap();
            } else if c.selection == Choice::Zfp.id() {
                ZfpCompressor::new(ZfpConfig::default()).decompress(&bytes).unwrap();
            }
        }
    }
}
