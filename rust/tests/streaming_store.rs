//! Integration tests for the streaming storage layer: the index-first
//! `ContainerV2Writer` (single-pass spill and two-pass recompress
//! protocols), per-chunk CRC verification, the pread-backed
//! `ByteSource` reader (with and without the LRU cache), and the
//! three wire-format bugfixes that rode along (10-byte varint
//! truncation, overlapping/gapped v2 chunk ranges, odd-length v1 raw
//! entries).

use adaptivec::baseline::Policy;
use adaptivec::codec::varint;
use adaptivec::codec_api::CodecRegistry;
use adaptivec::coordinator::spill::SpillConfig;
use adaptivec::coordinator::store::{
    ChunkDecl, Container, ContainerReader, ContainerV2Writer, FieldDecl,
};
use adaptivec::coordinator::{Coordinator, WritePlan};
use adaptivec::data::atm;
use adaptivec::data::field::Dims;
use adaptivec::data::Field;
use adaptivec::estimator::selector::{CandidateSet, SelectorConfig};
use adaptivec::testing::proptest_lite::{forall, Gen};

fn fields(seed: u64, n: usize) -> Vec<Field> {
    (0..n).map(|i| atm::generate_field_scaled(seed, i, 0)).collect()
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("adaptivec_streaming_{name}"))
}

#[test]
fn streamed_write_is_byte_identical_across_policies_and_plans() {
    let mut coord = Coordinator::new(SelectorConfig::default(), 3);
    let fs = fields(11, 3);
    for policy in [Policy::RateDistortion, Policy::NoCompression, Policy::AlwaysZfp] {
        for chunk_elems in [0usize, 2048] {
            let buffered = coord
                .run_chunked(&fs, policy, 1e-3, chunk_elems)
                .unwrap()
                .to_container()
                .to_bytes();
            for plan in [WritePlan::SinglePassSpill, WritePlan::TwoPassRecompress] {
                coord.write_plan = plan;
                let (report, streamed) = coord
                    .run_chunked_to(&fs, policy, 1e-3, chunk_elems, Vec::new())
                    .unwrap();
                assert!(
                    streamed == buffered,
                    "streamed and buffered outputs diverged: {policy:?} / {chunk_elems} / {plan:?}"
                );
                // The summary's totals agree with the parsed container.
                let reader = ContainerReader::from_bytes(buffered.clone()).unwrap();
                assert_eq!(report.total_stored_bytes(), reader.stored_bytes());
                assert_eq!(report.total_raw_bytes(), reader.raw_bytes());
            }
        }
    }
}

#[test]
fn single_pass_equals_two_pass_across_codec_sets() {
    // The write plan must be invisible in the bytes for every
    // candidate set the selector can rank (restricting candidates
    // changes which codecs the chunks pick, so each set exercises
    // different payload streams).
    let fs = fields(17, 2);
    for codecs in ["sz", "zfp", "dct", "sz,zfp", "sz,zfp,dct"] {
        let cfg = SelectorConfig {
            candidates: CandidateSet::parse(codecs).unwrap(),
            ..SelectorConfig::default()
        };
        let mut coord = Coordinator::new(cfg, 3);
        let mut outputs = Vec::new();
        for plan in [WritePlan::SinglePassSpill, WritePlan::TwoPassRecompress] {
            coord.write_plan = plan;
            let (report, bytes) = coord
                .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, Vec::new())
                .unwrap();
            // Single-pass: exactly one compress per chunk; two-pass:
            // exactly two.
            let expect = match plan {
                WritePlan::SinglePassSpill => report.total_chunks() as u64,
                WritePlan::TwoPassRecompress => 2 * report.total_chunks() as u64,
            };
            assert_eq!(report.compress_calls.total(), expect, "{codecs} / {plan:?}");
            outputs.push(bytes);
        }
        assert!(outputs[0] == outputs[1], "plans diverged for codec set {codecs}");
        let buffered = coord
            .run_chunked(&fs, Policy::RateDistortion, 1e-3, 2048)
            .unwrap()
            .to_container()
            .to_bytes();
        assert!(outputs[0] == buffered, "streamed != buffered for codec set {codecs}");
    }
}

/// An `io::Write` sink that fails once `limit` bytes have been
/// accepted — simulates the shared filesystem filling up mid-splice.
struct FailingSink {
    accepted: usize,
    limit: usize,
}

impl std::io::Write for FailingSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.accepted + buf.len() > self.limit {
            return Err(std::io::Error::other("sink full"));
        }
        self.accepted += buf.len();
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn scratch_file_cleaned_up_on_sink_failure() {
    // Force everything through a scratch file (zero memory budget,
    // private directory), then fail the sink at several points:
    // during the index write and during the splice. Every failure
    // must propagate as Err AND leave the scratch directory empty.
    let dir = tmp_path("scratch_cleanup_dir");
    std::fs::create_dir_all(&dir).unwrap();
    let mut coord = Coordinator::new(SelectorConfig::default(), 2);
    coord.spill = SpillConfig { mem_budget: 0, dir: Some(dir.clone()), shards: 0 };
    let fs = fields(23, 2);
    // Reference run to size the container, so the failure limits hit
    // each phase deterministically: 0 = the magic itself, 16 =
    // mid-index, len-1 = the very last payload write of the splice.
    let (_, full) = coord
        .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, Vec::new())
        .unwrap();
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "reference run leaked");
    for limit in [0usize, 16, full.len() - 1] {
        let sink = FailingSink { accepted: 0, limit };
        let result = coord.run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, sink);
        assert!(result.is_err(), "limit {limit}: a full sink must error");
        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            0,
            "limit {limit}: scratch file leaked"
        );
    }
    // And the success path leaves nothing behind either.
    let (report, bytes) = coord
        .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, Vec::new())
        .unwrap();
    assert!(report.scratch_spilled);
    assert!(report.peak_scratch_bytes > 0);
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "scratch leaked on success");
    // The spilled run still produced a valid, decodable container.
    let reader = ContainerReader::from_bytes(bytes).unwrap();
    assert_eq!(reader.version, 3);
    let restored = coord.load_reader(&reader).unwrap();
    assert_eq!(restored.len(), fs.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crc_catches_bit_rot_in_every_chunk() {
    // Flip one bit in each chunk's payload of a real container: the
    // v3 index CRC must turn every flip into a Corrupt error at
    // chunk_bytes/decode_chunk — including raw chunks, where decode
    // alone would silently return wrong values.
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let registry = CodecRegistry::default();
    let fs = fields(29, 2);
    for policy in [Policy::RateDistortion, Policy::NoCompression] {
        let (_, bytes) = coord
            .run_chunked_to(&fs, policy, 1e-3, 2048, Vec::new())
            .unwrap();
        let clean = ContainerReader::from_bytes(bytes.clone()).unwrap();
        assert_eq!(clean.version, 3);
        for (fi, f) in clean.fields.iter().enumerate() {
            for (ci, c) in f.chunks.iter().enumerate() {
                if c.len == 0 {
                    continue;
                }
                let mut corrupt = bytes.clone();
                corrupt[c.offset + c.len / 2] ^= 0x40;
                let r = ContainerReader::from_bytes(corrupt).unwrap();
                let err = r.chunk_bytes(fi, ci).unwrap_err();
                assert!(
                    format!("{err}").contains("crc"),
                    "{policy:?} field {fi} chunk {ci}: {err}"
                );
                assert!(r.decode_chunk(&registry, fi, ci).is_err());
                // Sibling chunks are untouched and still verify.
                if ci > 0 {
                    assert!(r.chunk_bytes(fi, ci - 1).is_ok());
                }
            }
        }
    }
}

#[test]
fn file_backed_pread_reader_equals_memory_reader_fuzz() {
    // Fuzz-style: random seeds, chunk granularities, and both wire
    // formats; every field and chunk must read and decode identically
    // through the in-memory buffer and the pread-backed file source.
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let registry = CodecRegistry::default();
    let gen = Gen::<(u64, usize, bool)>::new(|r| {
        let chunk_elems = [0usize, 1024, 2048, 4096][r.below(4)];
        (r.below(1 << 30) as u64, chunk_elems, r.bool(0.3))
    });
    forall("pread reader == memory reader", 6, gen, |&(seed, chunk_elems, v1)| {
        let fs = fields(seed, 2);
        let bytes = if v1 {
            coord.run(&fs, Policy::RateDistortion, 1e-3).unwrap().to_container().to_bytes()
        } else {
            let (_, b) = coord
                .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, chunk_elems, Vec::new())
                .unwrap();
            b
        };
        let path = tmp_path(&format!("eq_{seed}_{chunk_elems}_{v1}.bin"));
        std::fs::write(&path, &bytes).unwrap();
        let mem = ContainerReader::from_bytes(bytes).unwrap();
        let file = ContainerReader::open(&path).unwrap();
        let mut ok = mem.version == file.version
            && mem.fields == file.fields
            && mem.source_len() == file.source_len();
        for (fi, f) in mem.fields.iter().enumerate() {
            for ci in 0..f.chunks.len() {
                ok &= mem.chunk_bytes(fi, ci).unwrap() == file.chunk_bytes(fi, ci).unwrap();
                ok &= mem.decode_chunk(&registry, fi, ci).unwrap()
                    == file.decode_chunk(&registry, fi, ci).unwrap();
            }
            let a = mem.load_field(&registry, &f.name).unwrap();
            let b = file.load_field(&registry, &f.name).unwrap();
            ok &= a.data == b.data && a.dims == b.dims;
        }
        std::fs::remove_file(&path).ok();
        ok
    });
}

#[test]
fn raw_v1_container_roundtrips_through_file_source() {
    // NoCompression exercises the v1 raw-entry path (selection 2,
    // bare f32 LE bytes) through the pread-backed reader.
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fs = fields(5, 2);
    let bytes = coord.run(&fs, Policy::NoCompression, 1e-3).unwrap().to_container().to_bytes();
    let path = tmp_path("raw_v1.bin");
    std::fs::write(&path, &bytes).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.version, 1);
    let restored = coord.load_reader(&reader).unwrap();
    for (orig, rest) in fs.iter().zip(&restored) {
        assert_eq!(orig.data, rest.data, "{}", orig.name);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn writer_streams_through_a_file_sink() {
    let decls = vec![FieldDecl {
        name: "x".into(),
        dims: Dims::D1(4),
        raw_bytes: 16,
        chunk_elems: 2,
        chunks: vec![ChunkDecl::of(2, &[1u8; 8]), ChunkDecl::of(2, &[2u8; 8])],
    }];
    let path = tmp_path("writer_file_sink.bin");
    let sink = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    let mut w = ContainerV2Writer::new(sink, &decls).unwrap();
    w.write_chunk(&[1u8; 8]).unwrap();
    w.write_chunk(&[2u8; 8]).unwrap();
    w.finish().unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.version, 3);
    assert_eq!(reader.fields.len(), 1);
    assert_eq!(reader.chunk_bytes(0, 0).unwrap(), vec![1u8; 8]);
    assert_eq!(reader.chunk_bytes(0, 1).unwrap(), vec![2u8; 8]);
    // Out-of-order supply through a file sink, byte-identical result.
    let ooo = tmp_path("writer_file_sink_ooo.bin");
    let sink = std::io::BufWriter::new(std::fs::File::create(&ooo).unwrap());
    let mut w = ContainerV2Writer::new(sink, &decls).unwrap();
    w.put_chunk(1, &[2u8; 8]).unwrap();
    w.put_chunk(0, &[1u8; 8]).unwrap();
    w.finish().unwrap();
    assert_eq!(
        std::fs::read(&ooo).unwrap(),
        std::fs::read(&path).unwrap(),
        "completion-order writes must match index-order bytes"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&ooo).ok();
}

#[test]
fn truncated_file_rejected_by_pread_open() {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fs = fields(9, 1);
    let (_, bytes) = coord
        .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, Vec::new())
        .unwrap();
    for cut in [0, 4, 9, bytes.len() / 2, bytes.len() - 1] {
        let path = tmp_path(&format!("trunc_{cut}.bin"));
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(ContainerReader::open(&path).is_err(), "prefix of {cut} bytes parsed");
        std::fs::remove_file(&path).ok();
    }
}

// ---------------------------------------------------------------------------
// Regression tests for the three wire-format bugfixes
// ---------------------------------------------------------------------------

#[test]
fn regression_ten_byte_varint_high_bits_rejected() {
    // Before the fix, 10th-byte payload bits above bit 63 were shifted
    // out silently, so `[0xFF; 9] + 0x7F` decoded to the same value as
    // the canonical `[0xFF; 9] + 0x01` (u64::MAX) instead of erroring.
    let mut canonical = Vec::new();
    varint::write_u64(&mut canonical, u64::MAX);
    assert_eq!(canonical.len(), 10);
    let mut pos = 0;
    assert_eq!(varint::read_u64(&canonical, &mut pos).unwrap(), u64::MAX);
    let mut aliased = canonical.clone();
    aliased[9] = 0x7F;
    let mut pos = 0;
    assert!(varint::read_u64(&aliased, &mut pos).is_err());
}

/// Hand-build a v2 container with one two-chunk field at the given
/// (offset, len) pairs over a `payload`-byte payload region.
fn v2_two_chunks(ranges: [(u64, u64); 2], payload: usize) -> Vec<u8> {
    let mut index = Vec::new();
    varint::write_u64(&mut index, 1);
    varint::write_str(&mut index, "x");
    Dims::D1(4).encode(&mut index);
    varint::write_u64(&mut index, 16); // raw_bytes
    varint::write_u64(&mut index, 2); // chunk_elems
    varint::write_u64(&mut index, 2); // n_chunks
    for (off, len) in ranges {
        index.push(2); // raw selection
        varint::write_u64(&mut index, off);
        varint::write_u64(&mut index, len);
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"ADAPTC02");
    varint::write_u64(&mut bytes, index.len() as u64);
    bytes.extend_from_slice(&index);
    bytes.extend_from_slice(&vec![0u8; payload]);
    bytes
}

#[test]
fn regression_overlapping_and_gapped_indexes_rejected() {
    // Contiguous tiling (the writer's invariant) parses...
    assert!(ContainerReader::from_bytes(v2_two_chunks([(0, 8), (8, 8)], 16)).is_ok());
    // ...but overlap (payload aliased to both chunks), gaps
    // (unreferenced holes), and out-of-order ranges are corruption —
    // in memory and through the file source alike.
    let cases = [
        v2_two_chunks([(0, 8), (0, 8)], 16),  // overlap
        v2_two_chunks([(0, 8), (12, 4)], 16), // gap
        v2_two_chunks([(8, 8), (0, 8)], 16),  // out of order
    ];
    for (i, bytes) in cases.iter().enumerate() {
        let err = ContainerReader::from_bytes(bytes.clone()).unwrap_err();
        assert!(format!("{err}").contains("tiling"), "case {i}: {err}");
        let path = tmp_path(&format!("tiling_{i}.bin"));
        std::fs::write(&path, bytes).unwrap();
        assert!(ContainerReader::open(&path).is_err(), "case {i} parsed from file");
        std::fs::remove_file(&path).ok();
    }
}

/// Hand-build a v1 container with one raw (selection 2) entry of
/// `payload_len` bytes.
fn v1_raw_entry(payload_len: usize) -> Vec<u8> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"ADAPTC01");
    varint::write_u64(&mut bytes, 1);
    varint::write_str(&mut bytes, "r");
    bytes.push(2); // raw selection
    varint::write_u64(&mut bytes, payload_len as u64);
    varint::write_bytes(&mut bytes, &vec![0u8; payload_len]);
    bytes
}

#[test]
fn regression_odd_length_raw_v1_entry_rejected() {
    // A multiple of 4 parses and decodes losslessly...
    let good = v1_raw_entry(12);
    assert!(Container::from_bytes(&good).is_ok());
    let reader = ContainerReader::from_bytes(good).unwrap();
    let registry = CodecRegistry::default();
    let (data, _) = reader.decode_chunk(&registry, 0, 0).unwrap();
    assert_eq!(data, vec![0.0f32; 3]);
    // ...but a ragged raw payload is Corrupt at parse time in both v1
    // parsers, not a silent short read of f32s.
    for odd in [2usize, 5, 1023] {
        let bad = v1_raw_entry(odd);
        assert!(Container::from_bytes(&bad).is_err(), "{odd}-byte raw entry parsed (v1)");
        assert!(
            ContainerReader::from_bytes(bad).is_err(),
            "{odd}-byte raw entry parsed (reader)"
        );
    }
}
