//! Integration tests for the streaming storage layer: the index-first
//! `ContainerV2Writer`, the pread-backed `ByteSource` reader, and the
//! three wire-format bugfixes that rode along (10-byte varint
//! truncation, overlapping/gapped v2 chunk ranges, odd-length v1 raw
//! entries).

use adaptivec::baseline::Policy;
use adaptivec::codec::varint;
use adaptivec::codec_api::CodecRegistry;
use adaptivec::coordinator::store::{
    ChunkDecl, Container, ContainerReader, ContainerV2Writer, FieldDecl,
};
use adaptivec::coordinator::Coordinator;
use adaptivec::data::atm;
use adaptivec::data::field::Dims;
use adaptivec::data::Field;
use adaptivec::estimator::selector::SelectorConfig;
use adaptivec::testing::proptest_lite::{forall, Gen};

fn fields(seed: u64, n: usize) -> Vec<Field> {
    (0..n).map(|i| atm::generate_field_scaled(seed, i, 0)).collect()
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("adaptivec_streaming_{name}"))
}

#[test]
fn streamed_write_is_byte_identical_across_policies() {
    let coord = Coordinator::new(SelectorConfig::default(), 3);
    let fs = fields(11, 3);
    for policy in [Policy::RateDistortion, Policy::NoCompression, Policy::AlwaysZfp] {
        for chunk_elems in [0usize, 2048] {
            let buffered = coord
                .run_chunked(&fs, policy, 1e-3, chunk_elems)
                .unwrap()
                .to_container()
                .to_bytes();
            let (report, streamed) = coord
                .run_chunked_to(&fs, policy, 1e-3, chunk_elems, Vec::new())
                .unwrap();
            assert!(
                streamed == buffered,
                "streamed and buffered outputs diverged: {policy:?} / {chunk_elems}"
            );
            // The summary's totals agree with the parsed container.
            let reader = ContainerReader::from_bytes(buffered).unwrap();
            assert_eq!(report.total_stored_bytes(), reader.stored_bytes());
            assert_eq!(report.total_raw_bytes(), reader.raw_bytes());
        }
    }
}

#[test]
fn file_backed_pread_reader_equals_memory_reader_fuzz() {
    // Fuzz-style: random seeds, chunk granularities, and both wire
    // formats; every field and chunk must read and decode identically
    // through the in-memory buffer and the pread-backed file source.
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let registry = CodecRegistry::default();
    let gen = Gen::<(u64, usize, bool)>::new(|r| {
        let chunk_elems = [0usize, 1024, 2048, 4096][r.below(4)];
        (r.below(1 << 30) as u64, chunk_elems, r.bool(0.3))
    });
    forall("pread reader == memory reader", 6, gen, |&(seed, chunk_elems, v1)| {
        let fs = fields(seed, 2);
        let bytes = if v1 {
            coord.run(&fs, Policy::RateDistortion, 1e-3).unwrap().to_container().to_bytes()
        } else {
            let (_, b) = coord
                .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, chunk_elems, Vec::new())
                .unwrap();
            b
        };
        let path = tmp_path(&format!("eq_{seed}_{chunk_elems}_{v1}.bin"));
        std::fs::write(&path, &bytes).unwrap();
        let mem = ContainerReader::from_bytes(bytes).unwrap();
        let file = ContainerReader::open(&path).unwrap();
        let mut ok = mem.version == file.version
            && mem.fields == file.fields
            && mem.source_len() == file.source_len();
        for (fi, f) in mem.fields.iter().enumerate() {
            for ci in 0..f.chunks.len() {
                ok &= mem.chunk_bytes(fi, ci).unwrap() == file.chunk_bytes(fi, ci).unwrap();
                ok &= mem.decode_chunk(&registry, fi, ci).unwrap()
                    == file.decode_chunk(&registry, fi, ci).unwrap();
            }
            let a = mem.load_field(&registry, &f.name).unwrap();
            let b = file.load_field(&registry, &f.name).unwrap();
            ok &= a.data == b.data && a.dims == b.dims;
        }
        std::fs::remove_file(&path).ok();
        ok
    });
}

#[test]
fn raw_v1_container_roundtrips_through_file_source() {
    // NoCompression exercises the v1 raw-entry path (selection 2,
    // bare f32 LE bytes) through the pread-backed reader.
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fs = fields(5, 2);
    let bytes = coord.run(&fs, Policy::NoCompression, 1e-3).unwrap().to_container().to_bytes();
    let path = tmp_path("raw_v1.bin");
    std::fs::write(&path, &bytes).unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.version, 1);
    let restored = coord.load_reader(&reader).unwrap();
    for (orig, rest) in fs.iter().zip(&restored) {
        assert_eq!(orig.data, rest.data, "{}", orig.name);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn writer_streams_through_a_file_sink() {
    let decls = vec![FieldDecl {
        name: "x".into(),
        dims: Dims::D1(4),
        raw_bytes: 16,
        chunk_elems: 2,
        chunks: vec![
            ChunkDecl { selection: 2, len: 8 },
            ChunkDecl { selection: 2, len: 8 },
        ],
    }];
    let path = tmp_path("writer_file_sink.bin");
    let sink = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    let mut w = ContainerV2Writer::new(sink, &decls).unwrap();
    w.write_chunk(&[1u8; 8]).unwrap();
    w.write_chunk(&[2u8; 8]).unwrap();
    w.finish().unwrap();
    let reader = ContainerReader::open(&path).unwrap();
    assert_eq!(reader.version, 2);
    assert_eq!(reader.fields.len(), 1);
    assert_eq!(reader.chunk_bytes(0, 0).unwrap(), vec![1u8; 8]);
    assert_eq!(reader.chunk_bytes(0, 1).unwrap(), vec![2u8; 8]);
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_file_rejected_by_pread_open() {
    let coord = Coordinator::new(SelectorConfig::default(), 2);
    let fs = fields(9, 1);
    let (_, bytes) = coord
        .run_chunked_to(&fs, Policy::RateDistortion, 1e-3, 2048, Vec::new())
        .unwrap();
    for cut in [0, 4, 9, bytes.len() / 2, bytes.len() - 1] {
        let path = tmp_path(&format!("trunc_{cut}.bin"));
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(ContainerReader::open(&path).is_err(), "prefix of {cut} bytes parsed");
        std::fs::remove_file(&path).ok();
    }
}

// ---------------------------------------------------------------------------
// Regression tests for the three wire-format bugfixes
// ---------------------------------------------------------------------------

#[test]
fn regression_ten_byte_varint_high_bits_rejected() {
    // Before the fix, 10th-byte payload bits above bit 63 were shifted
    // out silently, so `[0xFF; 9] + 0x7F` decoded to the same value as
    // the canonical `[0xFF; 9] + 0x01` (u64::MAX) instead of erroring.
    let mut canonical = Vec::new();
    varint::write_u64(&mut canonical, u64::MAX);
    assert_eq!(canonical.len(), 10);
    let mut pos = 0;
    assert_eq!(varint::read_u64(&canonical, &mut pos).unwrap(), u64::MAX);
    let mut aliased = canonical.clone();
    aliased[9] = 0x7F;
    let mut pos = 0;
    assert!(varint::read_u64(&aliased, &mut pos).is_err());
}

/// Hand-build a v2 container with one two-chunk field at the given
/// (offset, len) pairs over a `payload`-byte payload region.
fn v2_two_chunks(ranges: [(u64, u64); 2], payload: usize) -> Vec<u8> {
    let mut index = Vec::new();
    varint::write_u64(&mut index, 1);
    varint::write_str(&mut index, "x");
    Dims::D1(4).encode(&mut index);
    varint::write_u64(&mut index, 16); // raw_bytes
    varint::write_u64(&mut index, 2); // chunk_elems
    varint::write_u64(&mut index, 2); // n_chunks
    for (off, len) in ranges {
        index.push(2); // raw selection
        varint::write_u64(&mut index, off);
        varint::write_u64(&mut index, len);
    }
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"ADAPTC02");
    varint::write_u64(&mut bytes, index.len() as u64);
    bytes.extend_from_slice(&index);
    bytes.extend_from_slice(&vec![0u8; payload]);
    bytes
}

#[test]
fn regression_overlapping_and_gapped_indexes_rejected() {
    // Contiguous tiling (the writer's invariant) parses...
    assert!(ContainerReader::from_bytes(v2_two_chunks([(0, 8), (8, 8)], 16)).is_ok());
    // ...but overlap (payload aliased to both chunks), gaps
    // (unreferenced holes), and out-of-order ranges are corruption —
    // in memory and through the file source alike.
    let cases = [
        v2_two_chunks([(0, 8), (0, 8)], 16),  // overlap
        v2_two_chunks([(0, 8), (12, 4)], 16), // gap
        v2_two_chunks([(8, 8), (0, 8)], 16),  // out of order
    ];
    for (i, bytes) in cases.iter().enumerate() {
        let err = ContainerReader::from_bytes(bytes.clone()).unwrap_err();
        assert!(format!("{err}").contains("tiling"), "case {i}: {err}");
        let path = tmp_path(&format!("tiling_{i}.bin"));
        std::fs::write(&path, bytes).unwrap();
        assert!(ContainerReader::open(&path).is_err(), "case {i} parsed from file");
        std::fs::remove_file(&path).ok();
    }
}

/// Hand-build a v1 container with one raw (selection 2) entry of
/// `payload_len` bytes.
fn v1_raw_entry(payload_len: usize) -> Vec<u8> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"ADAPTC01");
    varint::write_u64(&mut bytes, 1);
    varint::write_str(&mut bytes, "r");
    bytes.push(2); // raw selection
    varint::write_u64(&mut bytes, payload_len as u64);
    varint::write_bytes(&mut bytes, &vec![0u8; payload_len]);
    bytes
}

#[test]
fn regression_odd_length_raw_v1_entry_rejected() {
    // A multiple of 4 parses and decodes losslessly...
    let good = v1_raw_entry(12);
    assert!(Container::from_bytes(&good).is_ok());
    let reader = ContainerReader::from_bytes(good).unwrap();
    let registry = CodecRegistry::default();
    let (data, _) = reader.decode_chunk(&registry, 0, 0).unwrap();
    assert_eq!(data, vec![0.0f32; 3]);
    // ...but a ragged raw payload is Corrupt at parse time in both v1
    // parsers, not a silent short read of f32s.
    for odd in [2usize, 5, 1023] {
        let bad = v1_raw_entry(odd);
        assert!(Container::from_bytes(&bad).is_err(), "{odd}-byte raw entry parsed (v1)");
        assert!(
            ContainerReader::from_bytes(bad).is_err(),
            "{odd}-byte raw entry parsed (reader)"
        );
    }
}
