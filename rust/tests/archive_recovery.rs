//! Persistent-archive guarantees, end to end (DESIGN.md §14):
//!
//! * **kill-and-restart**: fields compressed through a service survive
//!   its death — a fresh service on the same archive root recovers the
//!   index from a shard scan and serves every field byte-identical to
//!   the offline `compress_chunked_to` + `load_field` path;
//! * **bounded residency**: with a zero memory budget every batch
//!   spills as it lands, asserted through the spill/evict counters and
//!   a zero hot-byte snapshot — the working set is bounded while the
//!   archive is not;
//! * **corruption containment**: a mangled shard file costs exactly
//!   the fields it held (skipped, counted), never the service.

use adaptivec::baseline::Policy;
use adaptivec::data::atm;
use adaptivec::data::field::{Dims, Field};
use adaptivec::engine::{Engine, EngineConfig};
use adaptivec::service::{ArchiveConfig, ArchiveStore, Service, ServiceConfig};
use std::path::PathBuf;
use std::sync::Arc;

const EB: f64 = 1e-3;
const CHUNK: usize = 2048;

fn engine() -> Arc<Engine> {
    Arc::new(Engine::new(EngineConfig { workers: 2, ..EngineConfig::default() }))
}

fn cfg(root: &PathBuf, mem_budget: usize) -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        queue_depth: 64,
        batch_max: 4,
        eb_rel: EB,
        chunk_elems: CHUNK,
        archive: ArchiveConfig {
            root_dir: Some(root.clone()),
            mem_budget,
            open_readers: 4,
            background_spill: true,
        },
        ..ServiceConfig::default()
    }
}

fn temp_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("adaptivec_recovery_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

/// Offline reference decode of one field, same knobs as the service.
fn offline(engine: &Engine, field: &Field) -> Field {
    let (_, bytes) = engine
        .compress_chunked_to(
            std::slice::from_ref(field),
            Policy::RateDistortion,
            EB,
            CHUNK,
            Vec::new(),
        )
        .unwrap();
    let reader = adaptivec::coordinator::store::ContainerReader::from_bytes(bytes).unwrap();
    engine.load_field(&reader, &field.name).unwrap()
}

#[test]
fn kill_and_restart_recovers_every_field_byte_identically() {
    let engine = engine();
    let root = temp_root("restart");
    let fields: Vec<Field> = (0..5).map(|i| atm::generate_field_scaled(81, i, 0)).collect();

    // First life: compress everything with a zero memory budget, so
    // every batch spills the moment it lands.
    {
        let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
        let handle = svc.handle();
        for f in &fields {
            handle.compress(f.clone()).unwrap();
        }
        let report = svc.shutdown();
        // Bounded residency, proven by the counters: everything that
        // came in was durably written and evicted, nothing stayed hot.
        assert!(report.archive.spills as usize >= 1, "zero budget must spill");
        assert_eq!(report.archive.spills, report.archive.evictions);
        assert_eq!(report.archive.hot_bytes, 0, "hot set must respect mem_budget 0");
        assert_eq!(report.archive.cold_fields, fields.len());
    }
    // The service is dead (dropped). Second life: same root, fresh
    // process state — the index must come back from the shard scan.
    {
        let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
        let report = svc.report();
        assert_eq!(report.archive.recovered_fields as usize, fields.len());
        assert!(report.archive.recovered_shards >= 1);
        assert_eq!(report.archive.corrupt_shards, 0);

        let handle = svc.handle();
        for f in &fields {
            let served = handle.fetch(&f.name).unwrap();
            let want = offline(&engine, f);
            assert_eq!(served.dims, want.dims, "{}", f.name);
            assert_eq!(
                served.data, want.data,
                "{}: fetch after restart diverged from the offline path",
                f.name
            );
        }
        // Cold fetches decode straight from shard files: residency
        // stays at zero even while serving the whole archive.
        let report = svc.report();
        assert_eq!(report.archive.hot_bytes, 0);
        assert!(report.archive.reader_hits + report.archive.reader_misses >= 1);
        svc.shutdown();
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn restart_after_ungraceful_budget_spill_still_serves_spilled_fields() {
    // Even without the shutdown flush, whatever the budget already
    // spilled is durable: kill the service right after compressing
    // under a zero budget and the next life still has everything.
    let engine = engine();
    let root = temp_root("ungraceful");
    let field = atm::generate_field_scaled(82, 0, 0);
    {
        let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
        svc.handle().compress(field.clone()).unwrap();
        // No explicit shutdown: Drop is the "kill".
    }
    let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
    let served = svc.handle().fetch(&field.name).unwrap();
    assert_eq!(served.data, offline(&engine, &field).data);
    svc.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn corrupt_shard_is_contained_to_its_own_fields() {
    let engine = engine();
    let root = temp_root("corrupt");
    let keep = atm::generate_field_scaled(83, 0, 0);
    let lose = atm::generate_field_scaled(83, 1, 0);
    {
        let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
        let handle = svc.handle();
        handle.compress(keep.clone()).unwrap();
        handle.compress(lose.clone()).unwrap();
        svc.shutdown();
    }
    // Mangle the shard file holding `lose` (identified by scanning the
    // tree for the file whose index carries that name).
    let mut mangled = 0;
    for dir in std::fs::read_dir(&root).unwrap() {
        let dir = dir.unwrap().path();
        if !dir.is_dir() {
            continue;
        }
        for f in std::fs::read_dir(&dir).unwrap() {
            let p = f.unwrap().path();
            let reader = adaptivec::coordinator::store::ContainerReader::open(&p).unwrap();
            if reader.field_names().any(|n| n == lose.name) {
                std::fs::write(&p, b"garbage, not a container").unwrap();
                mangled += 1;
            }
        }
    }
    assert_eq!(mangled, 1, "exactly one shard holds the mangled field");

    let svc = Service::start(Arc::clone(&engine), cfg(&root, 0)).unwrap();
    let report = svc.report();
    assert_eq!(report.archive.corrupt_shards, 1, "corruption is counted, not fatal");
    let handle = svc.handle();
    let served = handle.fetch(&keep.name).unwrap();
    assert_eq!(served.data, offline(&engine, &keep).data, "healthy shard unaffected");
    assert!(handle.fetch(&lose.name).is_err(), "mangled shard's field is gone, not wrong");
    svc.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Torn-write recovery, exhaustively: a shard file truncated at
/// *every* byte boundary (a crashed write, a partial copy, a torn
/// block) must never panic the open and never decode to wrong bytes —
/// the only allowed outcomes are "skipped and counted corrupt" or
/// "absent" or "byte-identical".
#[test]
fn truncated_shard_at_every_byte_boundary_is_contained() {
    let engine = engine();
    let root = temp_root("truncate");
    // A deliberately tiny field: the loop below reopens the archive
    // once per byte of the published shard.
    let data: Vec<f32> = (0..128).map(|i| (i as f32 * 0.37).sin()).collect();
    let field = Field::new("torn-probe", Dims::D2(8, 16), data);
    let want = offline(&engine, &field);

    let store_cfg = ArchiveConfig {
        root_dir: Some(root.clone()),
        mem_budget: 0,
        open_readers: 4,
        background_spill: true,
    };
    {
        let store = ArchiveStore::open(store_cfg.clone(), 4).unwrap();
        let (_, bytes) = engine
            .compress_chunked_to(
                std::slice::from_ref(&field),
                Policy::RateDistortion,
                EB,
                CHUNK,
                Vec::new(),
            )
            .unwrap();
        store.insert(vec![field.name.clone()], bytes).unwrap();
        store.quiesce();
        assert_eq!(store.stats().spills, 1, "zero budget publishes exactly one shard");
    }
    // Locate the single shard file just published.
    let mut shards = Vec::new();
    for dir in std::fs::read_dir(&root).unwrap() {
        let dir = dir.unwrap().path();
        if dir.is_dir() {
            for f in std::fs::read_dir(&dir).unwrap() {
                shards.push(f.unwrap().path());
            }
        }
    }
    assert_eq!(shards.len(), 1, "expected exactly one shard file");
    let shard = shards.pop().unwrap();
    let whole = std::fs::read(&shard).unwrap();
    assert!(whole.len() > 8, "shard implausibly small: {} bytes", whole.len());

    for cut in 0..whole.len() {
        std::fs::write(&shard, &whole[..cut]).unwrap();
        let store = ArchiveStore::open(store_cfg.clone(), 4)
            .unwrap_or_else(|e| panic!("open must survive truncation at byte {cut}: {e}"));
        let stats = store.stats();
        if stats.corrupt_shards == 1 {
            // Skipped and counted: the field is absent, not wrong.
            assert!(
                store.reader_for(&field.name).unwrap().is_none(),
                "byte {cut}: a corrupt shard must not index its fields"
            );
        } else {
            // The index happened to parse. Decoding must then yield
            // exactly the original bytes or a clean error — the
            // per-stream lengths and CRC-32 make "plausible but
            // wrong" unreachable.
            assert_eq!(stats.corrupt_shards, 0);
            if let Some(reader) = store.reader_for(&field.name).unwrap() {
                if let Ok(served) = engine.load_field(&reader, &field.name) {
                    assert_eq!(
                        served.data, want.data,
                        "byte {cut}: truncated shard decoded to different bytes"
                    );
                }
            }
        }
    }

    // Restore the full shard: everything comes back, nothing sticky.
    std::fs::write(&shard, &whole).unwrap();
    let store = ArchiveStore::open(store_cfg, 4).unwrap();
    assert_eq!(store.stats().corrupt_shards, 0);
    let reader = store.reader_for(&field.name).unwrap().expect("restored shard indexes");
    assert_eq!(engine.load_field(&reader, &field.name).unwrap().data, want.data);
    drop(store);
    std::fs::remove_dir_all(&root).ok();
}
