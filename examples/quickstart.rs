//! Quickstart: compress one climate field with the automatic online
//! selector, inspect the decision, verify the error bound, round-trip.
//!
//! Run: `cargo run --release --example quickstart`

use adaptivec::data::atm;
use adaptivec::estimator::selector::{AutoSelector, SelectorConfig};
use adaptivec::metrics::error_stats;

fn main() -> adaptivec::Result<()> {
    // 1. A field: one variable of the synthetic CESM-ATM dataset.
    let field = atm::generate_field(2018, 0);
    println!(
        "field {} ({}), {} values, range {:.4}",
        field.name,
        field.dims,
        field.len(),
        field.value_range()
    );

    // 2. The selector (Algorithm 1 of the paper): 5% sampling.
    let selector = AutoSelector::new(SelectorConfig::default());
    let eb_rel = 1e-4; // value-range-relative error bound

    // 3. Estimate + select + compress in one call.
    let out = selector.compress(&field, eb_rel)?;
    println!(
        "picked {}: estimated BR_sz {:.2} vs BR_zfp {:.2} bits/value @ target PSNR {:.1} dB",
        out.choice.name(),
        out.estimates.br_sz,
        out.estimates.br_zfp,
        out.estimates.psnr_target
    );
    println!(
        "compressed {} -> {} bytes (ratio {:.2}, {:.2} bits/value)",
        out.raw_bytes,
        out.container.len(),
        out.ratio(),
        out.bit_rate()
    );

    // 4. Round-trip and verify the pointwise bound.
    let recon = selector.decompress(&out.container)?;
    let stats = error_stats(&field.data, &recon);
    let bound = eb_rel * field.value_range();
    println!(
        "max |err| {:.3e} <= bound {:.3e}; PSNR {:.1} dB",
        stats.max_abs_err, bound, stats.psnr
    );
    assert!(stats.max_abs_err <= bound * (1.0 + 1e-9));
    println!("quickstart OK");
    Ok(())
}
