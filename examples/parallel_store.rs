//! END-TO-END driver (the validation run recorded in EXPERIMENTS.md):
//! generate all three datasets, run the full engine under every
//! policy, write/read real container files, verify every field's error
//! bound, and report the paper's headline metrics: compression ratios
//! (Fig. 7 protocol) and modeled 1..1024-rank store/load throughput
//! (Figs. 8–9), with compression time *measured* on this machine.
//!
//! Run: `cargo run --release --example parallel_store`

use adaptivec::baseline::Policy;
use adaptivec::coordinator::store::{Container, ContainerReader};
use adaptivec::data::Dataset;
use adaptivec::engine::Engine;
use adaptivec::iosim::{FsModel, ThroughputModel, PROC_SWEEP};
use adaptivec::metrics::error_stats;
use std::time::Instant;

fn main() -> adaptivec::Result<()> {
    let engine = Engine::default();
    let registry = engine.registry();
    let eb_rel = 1e-4;
    let tmp = std::env::temp_dir().join("adaptivec_parallel_store");
    std::fs::create_dir_all(&tmp)?;

    println!("workers: {}, eb_rel: {eb_rel:.0e}", engine.workers());

    let mut hurricane_stats: Vec<(Policy, f64, f64, f64, f64)> = Vec::new();

    for ds in Dataset::ALL {
        let fields = ds.generate(2018, 1);
        let raw: u64 = fields.iter().map(|f| f.raw_bytes() as u64).sum();
        println!(
            "\n=== {} — {} fields, {:.1} MB raw ===",
            ds.name(),
            fields.len(),
            raw as f64 / 1e6
        );
        println!(
            "{:<10} {:>8} {:>10} {:>10} {:>22}",
            "policy", "ratio", "comp(s)", "decomp(s)", "codec picks"
        );

        for policy in [
            Policy::NoCompression,
            Policy::AlwaysSz,
            Policy::AlwaysZfp,
            Policy::AlwaysDct,
            Policy::ErrorBound,
            Policy::RateDistortion,
            Policy::Optimum,
        ] {
            let t0 = Instant::now();
            let report = engine.run(&fields, policy, eb_rel)?;
            let comp_wall = t0.elapsed().as_secs_f64();

            // Real file I/O round-trip.
            let path = tmp.join(format!("{}_{}.adaptivec", ds.name(), policy.name()));
            report.to_container().write_file(&path)?;
            let t1 = Instant::now();
            let container = Container::read_file(&path)?;
            let restored = if policy == Policy::NoCompression {
                Vec::new() // raw entries hold LE bytes; skip decode
            } else {
                engine.load(&container)?
            };
            let decomp_wall = t1.elapsed().as_secs_f64();

            // Verify error bounds on every restored field.
            for (orig, rest) in fields.iter().zip(&restored) {
                let vr = orig.value_range();
                let bound = if vr > 0.0 { eb_rel * vr } else { eb_rel };
                let stats = error_stats(&orig.data, &rest.data);
                assert!(
                    stats.max_abs_err <= bound * (1.0 + 1e-6),
                    "{} {} {}: {} > {}",
                    ds.name(),
                    policy.name(),
                    orig.name,
                    stats.max_abs_err,
                    bound
                );
            }

            println!(
                "{:<10} {:>8.2} {:>10.2} {:>10.2} {:>22}",
                policy.name(),
                report.overall_ratio(),
                comp_wall,
                decomp_wall,
                report.codec_counts().summary(registry)
            );

            if ds == Dataset::Hurricane {
                hurricane_stats.push((
                    policy,
                    report.total_raw_bytes() as f64,
                    report.total_stored_bytes() as f64,
                    report.total_compress_time().as_secs_f64()
                        + report.total_estimate_time().as_secs_f64(),
                    0.12 * report.total_compress_time().as_secs_f64(), // decompression ~ measured below
                ));
            }
        }
    }

    // --- Figs. 8–9: modeled parallel store/load throughput on the
    // Hurricane dataset (paper's §6.5 configuration), compression time
    // measured above, per-process share = dataset / process.
    println!("\n=== modeled store throughput (GB/s of raw data), Hurricane, eb 1e-4 ===");
    let tm = ThroughputModel::new(FsModel::default());
    print!("{:>6}", "procs");
    for (p, ..) in &hurricane_stats {
        print!(" {:>10}", p.name());
    }
    println!();
    for &procs in &PROC_SWEEP {
        print!("{procs:>6}");
        for &(_, raw, stored, comp_t, _) in &hurricane_stats {
            // Each rank holds one dataset replica (weak scaling, as in
            // file-per-process runs); per-rank compute time is the
            // single-rank total divided across its own cores=1.
            let tput = tm.store_throughput(procs, raw, stored, comp_t);
            print!(" {:>10.2}", tput / 1e9);
        }
        println!();
    }

    // --- streamed v2 store + pread-backed partial load: the chunked
    // container flows straight to disk through the index-first writer
    // (full payload never resident), then one field is reconstructed
    // by reading only its indexed chunk ranges back.
    println!("\n=== streamed v2 store + pread partial load (Hurricane) ===");
    let fields = Dataset::Hurricane.generate(2018, 1);
    let path = tmp.join("hurricane_streamed.adaptivec2");
    let sink = std::io::BufWriter::new(std::fs::File::create(&path)?);
    let (srep, _) =
        engine.compress_chunked_to(&fields, Policy::RateDistortion, eb_rel, 64 * 1024, sink)?;
    println!(
        "streamed {} fields ({}): ratio {:.2}, peak payload {} B vs {} B buffered ({:.1}%); \
         {} codec calls for {} chunks, peak scratch {} B{}",
        srep.fields.len(),
        srep.write_plan.name(),
        srep.overall_ratio(),
        srep.peak_payload_bytes,
        srep.total_stored_bytes(),
        srep.peak_payload_frac() * 100.0,
        srep.compress_calls.total(),
        srep.total_chunks(),
        srep.peak_scratch_bytes,
        if srep.scratch_spilled { " (spilled to temp file)" } else { " (in memory)" }
    );
    assert_eq!(
        srep.compress_calls.total(),
        srep.total_chunks() as u64,
        "single-pass writer must compress each chunk exactly once"
    );
    let reader = ContainerReader::open(&path)?; // index-only pread open
    let target = &fields[fields.len() / 2];
    let got = engine.load_field(&reader, &target.name)?;
    let vr = target.value_range();
    let bound = if vr > 0.0 { eb_rel * vr } else { eb_rel };
    let stats = error_stats(&target.data, &got.data);
    assert!(stats.max_abs_err <= bound * (1.0 + 1e-6), "partial load broke the bound");
    let (_, info) = reader.field(&target.name)?;
    println!(
        "partial load '{}': read {} payload + {} index bytes of a {}-byte container",
        target.name,
        info.stored_bytes(),
        reader.index_bytes(),
        reader.source_len()
    );

    std::fs::remove_dir_all(&tmp).ok();
    println!("\nparallel_store OK — all bounds verified");
    Ok(())
}
