//! Rate-distortion curves (the paper's core comparison, §5.1.3):
//! sweep error bounds, plot PSNR vs bit-rate for SZ, ZFP, and the
//! automatic selector on representative fields of all three datasets.
//!
//! Run: `cargo run --release --example rate_distortion`

use adaptivec::data::{atm, hurricane, nyx, Field};
use adaptivec::estimator::eval;
use adaptivec::estimator::selector::AutoSelector;
use adaptivec::metrics::error_stats;

fn rd_point_auto(sel: &AutoSelector, f: &Field, eb_rel: f64) -> (f64, f64) {
    let out = sel.compress(f, eb_rel).unwrap();
    let recon = sel.decompress(&out.container).unwrap();
    let stats = error_stats(&f.data, &recon);
    (out.bit_rate(), stats.psnr)
}

fn main() -> adaptivec::Result<()> {
    let sel = AutoSelector::default();
    let fields = vec![
        atm::generate_field(2018, 0),      // smooth climate field
        atm::generate_field(2018, 7),      // rough climate field
        hurricane::generate_field(2018, 7), // vortex velocity U
        nyx::generate_field(2018, 0),      // cosmology density
    ];
    let bounds = [1e-2, 1e-3, 1e-4, 1e-5, 1e-6];

    for f in &fields {
        println!("\n=== rate-distortion: {} ({}) ===", f.name, f.dims);
        println!(
            "{:>8} | {:>8} {:>8} | {:>8} {:>8} | {:>8} {:>8} {:>6}",
            "eb_rel", "SZ br", "SZ dB", "ZFP br", "ZFP dB", "auto br", "auto dB", "pick"
        );
        for &eb in &bounds {
            let vr = f.value_range();
            let eb_abs = eb * vr;
            let sz = eval::measure_sz(f, eb_abs)?;
            let zfp = eval::measure_zfp(f, eb_abs)?;
            let (choice, _) = sel.select(f, eb)?;
            let (abr, apsnr) = rd_point_auto(&sel, f, eb);
            println!(
                "{eb:>8.0e} | {:>8.3} {:>8.2} | {:>8.3} {:>8.2} | {:>8.3} {:>8.2} {:>6}",
                sz.bit_rate, sz.psnr, zfp.bit_rate, zfp.psnr, abr, apsnr,
                choice.name()
            );
        }
    }
    println!("\nHigher PSNR at equal bit-rate = better rate-distortion.");
    Ok(())
}
