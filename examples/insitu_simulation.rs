//! In-situ compression driver (paper §3's "practical in situ model"):
//! a small 2D advection–diffusion simulation produces evolving fields;
//! after every simulation step the engine compresses the state
//! in-memory with the online selector, exactly as an HPC code would
//! hand its analysis output to the compression layer before I/O.
//!
//! Demonstrates: per-timestep selection stability, accumulated ratio,
//! and that compression error does NOT feed back into the simulation
//! (compression is on the output path only). Each output step is
//! *streamed* to its own v2 container file through the index-first
//! writer — the compressed payload is never buffered whole, exactly
//! the bounded-memory discipline an in-situ pipeline needs — and then
//! verified by reading the file back through the pread-backed reader.
//!
//! Run: `cargo run --release --example insitu_simulation`

use adaptivec::baseline::Policy;
use adaptivec::coordinator::store::ContainerReader;
use adaptivec::data::field::{Dims, Field};
use adaptivec::engine::Engine;
use adaptivec::metrics::error_stats;
use adaptivec::testing::Rng;

/// Toy periodic 2D advection–diffusion: ∂u/∂t = −v·∇u + κ∇²u + forcing.
struct Sim {
    ny: usize,
    nx: usize,
    /// Scalar tracer (temperature-like).
    u: Vec<f32>,
    /// Vorticity-derived velocity (fixed rotational flow).
    vx: Vec<f32>,
    vy: Vec<f32>,
    rng: Rng,
}

impl Sim {
    fn new(ny: usize, nx: usize, seed: u64) -> Sim {
        let mut rng = Rng::new(seed);
        let u = adaptivec::data::spectral::grf_2d(&mut rng, ny, nx, 3.0);
        let (cx, cy) = (nx as f64 / 2.0, ny as f64 / 2.0);
        let mut vx = vec![0.0f32; ny * nx];
        let mut vy = vec![0.0f32; ny * nx];
        for y in 0..ny {
            for x in 0..nx {
                let (dx, dy) = (x as f64 - cx, y as f64 - cy);
                let r = (dx * dx + dy * dy).sqrt().max(1.0);
                vx[y * nx + x] = (-dy / r) as f32 * 0.8;
                vy[y * nx + x] = (dx / r) as f32 * 0.8;
            }
        }
        Sim { ny, nx, u, vx, vy, rng }
    }

    /// One explicit Euler step (upwind advection + 5-point diffusion).
    fn step(&mut self) {
        let (ny, nx) = (self.ny, self.nx);
        let kappa = 0.12;
        let dt = 0.5;
        let mut next = self.u.clone();
        for y in 0..ny {
            for x in 0..nx {
                let i = y * nx + x;
                let xm = y * nx + (x + nx - 1) % nx;
                let xp = y * nx + (x + 1) % nx;
                let ym = ((y + ny - 1) % ny) * nx + x;
                let yp = ((y + 1) % ny) * nx + x;
                let lap = self.u[xm] + self.u[xp] + self.u[ym] + self.u[yp]
                    - 4.0 * self.u[i];
                let (vx, vy) = (self.vx[i], self.vy[i]);
                let dudx = if vx > 0.0 { self.u[i] - self.u[xm] } else { self.u[xp] - self.u[i] };
                let dudy = if vy > 0.0 { self.u[i] - self.u[ym] } else { self.u[yp] - self.u[i] };
                next[i] = self.u[i] + dt * (kappa * lap - vx * dudx - vy * dudy);
            }
        }
        // Weak stochastic forcing keeps the field from diffusing flat.
        for _ in 0..8 {
            let y = self.rng.below(ny);
            let x = self.rng.below(nx);
            next[y * nx + x] += self.rng.gauss() as f32 * 0.05;
        }
        self.u = next;
    }

    /// Snapshot the state as dataset fields (tracer + velocities).
    fn snapshot(&self, step: usize) -> Vec<Field> {
        let dims = Dims::D2(self.ny, self.nx);
        vec![
            Field::new(format!("tracer_t{step:04}"), dims, self.u.clone()),
            Field::new(format!("vx_t{step:04}"), dims, self.vx.clone()),
            Field::new(format!("vy_t{step:04}"), dims, self.vy.clone()),
        ]
    }
}

fn main() -> adaptivec::Result<()> {
    let mut sim = Sim::new(192, 192, 42);
    let engine = Engine::default();
    let eb_rel = 1e-4;
    let steps = 40;
    let output_every = 4;
    let chunk_elems = 16 * 1024;
    let tmp = std::env::temp_dir().join("adaptivec_insitu");
    std::fs::create_dir_all(&tmp)?;

    println!("in-situ simulation: 192x192 advection-diffusion, {steps} steps, output every {output_every}");
    let registry = engine.registry();
    println!(
        "{:>6} {:>8} {:>18} {:>10} {:>12}",
        "step", "ratio", "codec picks", "max|err|", "bound"
    );

    let (mut total_raw, mut total_stored) = (0u64, 0u64);
    let (mut peak_payload, mut outputs) = (0u64, 0u64);
    let (mut peak_scratch, mut compress_calls, mut total_chunks) = (0u64, 0u64, 0u64);
    for step in 0..steps {
        sim.step();
        if step % output_every != 0 {
            continue;
        }
        let fields = sim.snapshot(step);
        // Stream this step's state straight to its own container file
        // (file-per-timestep, the paper's file-per-process I/O shape).
        // The default single-pass plan compresses each chunk exactly
        // once, spilling payloads to scratch until the index settles.
        let path = tmp.join(format!("step{step:04}.adaptivec2"));
        let sink = std::io::BufWriter::new(std::fs::File::create(&path)?);
        let (report, _) =
            engine.compress_chunked_to(&fields, Policy::RateDistortion, eb_rel, chunk_elems, sink)?;
        total_raw += report.total_raw_bytes();
        total_stored += report.total_stored_bytes();
        peak_payload = peak_payload.max(report.peak_payload_bytes);
        peak_scratch = peak_scratch.max(report.peak_scratch_bytes);
        compress_calls += report.compress_calls.total();
        total_chunks += report.total_chunks() as u64;
        outputs += 1;

        // Verify in-situ output quality by reading the step file back
        // through the pread-backed reader.
        let reader = ContainerReader::open(&path)?;
        let restored = engine.load_reader(&reader)?;
        std::fs::remove_file(&path).ok();
        let mut worst = (0.0f64, 0.0f64);
        for (orig, rest) in fields.iter().zip(&restored) {
            let vr = orig.value_range();
            let bound = if vr > 0.0 { eb_rel * vr } else { eb_rel };
            let stats = error_stats(&orig.data, &rest.data);
            assert!(stats.max_abs_err <= bound * (1.0 + 1e-6), "{}", orig.name);
            if stats.max_abs_err > worst.0 {
                worst = (stats.max_abs_err, bound);
            }
        }
        println!(
            "{:>6} {:>8.2} {:>18} {:>10.2e} {:>12.2e}",
            step,
            report.overall_ratio(),
            report.codec_counts().summary(registry),
            worst.0,
            worst.1
        );
    }
    println!(
        "\naccumulated: {:.1} MB raw -> {:.1} MB stored (ratio {:.2}); \
         peak in-memory payload {:.1} KB vs {:.1} KB avg stored per step; \
         {compress_calls} codec calls for {total_chunks} chunks \
         (single-pass: compressed once), peak scratch {:.1} KB",
        total_raw as f64 / 1e6,
        total_stored as f64 / 1e6,
        total_raw as f64 / total_stored as f64,
        peak_payload as f64 / 1e3,
        total_stored as f64 / outputs.max(1) as f64 / 1e3,
        peak_scratch as f64 / 1e3
    );
    assert_eq!(
        compress_calls, total_chunks,
        "single-pass writer must invoke each codec exactly once per chunk"
    );
    std::fs::remove_dir_all(&tmp).ok();
    println!("insitu_simulation OK — all bounds verified");
    Ok(())
}
