//! Estimator accuracy demo (§6.2): per-field estimated vs measured
//! bit-rate and PSNR for both compressors, plus selection accuracy
//! against the iso-PSNR oracle.
//!
//! Run: `cargo run --release --example estimator_accuracy`

use adaptivec::data::Dataset;
use adaptivec::estimator::eval;
use adaptivec::estimator::selector::AutoSelector;

fn main() -> adaptivec::Result<()> {
    let sel = AutoSelector::default();
    for ds in Dataset::ALL {
        let fields = ds.generate(2018, 1);
        println!("\n=== {} ({} fields) ===", ds.name(), fields.len());
        println!(
            "{:<22} {:>8} {:>8} {:>8} {:>8} {:>6} {:>6} {:>3}",
            "field", "estBRsz", "realBRsz", "estBRzfp", "realBRzfp", "pick", "orcl", "ok"
        );
        let mut evals = Vec::new();
        for f in &fields {
            if f.value_range() <= 0.0 {
                continue;
            }
            let ev = eval::evaluate_field(&sel, f, 1e-4)?;
            println!(
                "{:<22} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>6} {:>6} {:>3}",
                ev.name,
                ev.est_br_sz,
                ev.real_sz.bit_rate,
                ev.est_br_zfp,
                ev.real_zfp.bit_rate,
                ev.picked.name(),
                ev.oracle.name(),
                if ev.correct() { "y" } else { "N" }
            );
            evals.push(ev);
        }
        let s = eval::aggregate_rel_errors(&evals);
        println!(
            "summary: selection accuracy {:.1}% | BR err (mean%) SZ {:+.1} ZFP {:+.1} | \
             PSNR err SZ {:+.1} ZFP {:+.1}",
            s.accuracy * 100.0,
            s.br_sz.0,
            s.br_zfp.0,
            s.psnr_sz.0,
            s.psnr_zfp.0
        );
    }
    Ok(())
}
